//! Table 1: theoretical cost of every parallelism implementation ± CDP,
//! from (a) the closed forms and (b) the discrete-time simulation, plus a
//! measured cross-check of the comm columns from the real trainers on the
//! mlp bundle.

mod harness;

use std::sync::Arc;

use cyclic_dp::coordinator::{multi, zero, SharedBackend};
use cyclic_dp::parallel::Rule;
use cyclic_dp::runtime::{Backend, NativeBackend};
use cyclic_dp::sim::{analytic, schemes, Scheme, SymbolicCosts};
use cyclic_dp::tensor::ops::{self, set_kernel_mode, KernelMode};
use cyclic_dp::util::stats::fmt_bytes;

fn main() {
    let b = harness::Bench::new("table1_costs");

    b.section("analytic Table 1 (paper units)");
    for n in [3usize, 4, 8] {
        print!("{}", analytic::render_table1(n));
    }

    b.section("discrete simulation cross-check (N = 4, mlp-sized model)");
    let c = SymbolicCosts {
        psi_p: 4 * 141_706,     // mlp bundle Ψ_P
        b_psi_a: 8 * 128 * 4 * 10, // rough B·Ψ_A
        b_psi_a_int: 8 * 128 * 4,
    };
    for s in Scheme::all() {
        println!("{}", schemes::render_scheme(s, 4, c));
    }

    // comm volume/message counts come from the fabric's host mirrors, so
    // they are backend-independent — measure on the native backend (an
    // on-disk mlp bundle when built, else the synthetic one)
    b.section("measured comm from real trainers (native mlp bundle, 4 steps)");
    let rt = SharedBackend(Arc::new(NativeBackend::load_or_synthetic("mlp").unwrap()));
    let psi_p = rt.manifest().psi_p_bytes();

    let dp = multi::train(rt.clone(), Rule::Dp, multi::CommPattern::Barrier, 4).unwrap();
    println!(
        "Multi-GPU DP      : {} total ({:.2} Ψ_P/step), {} msgs, {} optimizer replicas",
        fmt_bytes(dp.comm_bytes),
        dp.comm_bytes as f64 / 4.0 / psi_p as f64,
        dp.comm_messages,
        dp.optimizer_replicas
    );
    let ring =
        multi::train(rt.clone(), Rule::CdpV2, multi::CommPattern::Ring, 4).unwrap();
    println!(
        "Multi-GPU + Cyclic: {} total ({:.2} Ψ_P/step), {} msgs, {} optimizer replica",
        fmt_bytes(ring.comm_bytes),
        ring.comm_bytes as f64 / 4.0 / psi_p as f64,
        ring.comm_messages,
        ring.optimizer_replicas
    );
    let zb = zero::train(rt.clone(), Rule::Dp, zero::StateFlow::Broadcast, 4).unwrap();
    let zc = zero::train(rt.clone(), Rule::CdpV2, zero::StateFlow::Cyclic, 4).unwrap();
    println!(
        "ZeRO-DP           : {} total, max msgs/timestep {}",
        fmt_bytes(zb.comm_bytes),
        zb.max_msgs_per_timestep
    );
    println!(
        "ZeRO-DP + Cyclic  : {} total, max msgs/timestep {}",
        fmt_bytes(zc.comm_bytes),
        zc.max_msgs_per_timestep
    );

    // ---- dense-kernel cross-check: fast vs retained scalar reference ------
    // Times the three matmul variants in both dispatch modes on a
    // trainer-sized shape and asserts bit-equality while at it — the same
    // contract the kernel_equivalence property suite enforces, visible
    // here next to the wall-clock gap it buys.
    b.section("dense kernels: fast vs scalar reference (b=64, 512×512)");
    cyclic_dp::util::par::warm();
    let (m, k, n) = (64usize, 512usize, 512usize);
    let a: Vec<f32> = (0..m * k).map(|i| ((i * 37) % 101) as f32 * 0.01 - 0.5).collect();
    let w: Vec<f32> = (0..k * n).map(|i| ((i * 53) % 97) as f32 * 0.01 - 0.48).collect();
    let g: Vec<f32> = (0..m * n).map(|i| ((i * 29) % 89) as f32 * 0.01 - 0.44).collect();
    let mut fast = vec![0.0f32; m * n];
    let mut slow = vec![0.0f32; m * n];
    let mut fast_tn = vec![0.0f32; k * n];
    let mut slow_tn = vec![0.0f32; k * n];
    let mut fast_nt = vec![0.0f32; m * k];
    let mut slow_nt = vec![0.0f32; m * k];

    set_kernel_mode(KernelMode::Fast);
    b.time("matmul fast", 2, 20, || {
        fast.iter_mut().for_each(|v| *v = 0.0);
        ops::matmul(&mut fast, &a, &w, m, k, n);
    });
    b.time("matmul_tn fast", 2, 20, || {
        ops::matmul_tn(&mut fast_tn, &a, &g, m, k, n);
    });
    b.time("matmul_nt_acc fast", 2, 20, || {
        fast_nt.iter_mut().for_each(|v| *v = 0.0);
        ops::matmul_nt_acc(&mut fast_nt, &g, &w, m, n, k);
    });
    set_kernel_mode(KernelMode::ScalarReference);
    b.time("matmul scalar", 2, 20, || {
        slow.iter_mut().for_each(|v| *v = 0.0);
        ops::matmul(&mut slow, &a, &w, m, k, n);
    });
    b.time("matmul_tn scalar", 2, 20, || {
        ops::matmul_tn(&mut slow_tn, &a, &g, m, k, n);
    });
    b.time("matmul_nt_acc scalar", 2, 20, || {
        slow_nt.iter_mut().for_each(|v| *v = 0.0);
        ops::matmul_nt_acc(&mut slow_nt, &g, &w, m, n, k);
    });
    set_kernel_mode(KernelMode::Fast);

    let bits_eq = |x: &[f32], y: &[f32]| x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits());
    assert!(bits_eq(&fast, &slow), "matmul fast/scalar bit mismatch");
    assert!(bits_eq(&fast_tn, &slow_tn), "matmul_tn fast/scalar bit mismatch");
    assert!(bits_eq(&fast_nt, &slow_nt), "matmul_nt_acc fast/scalar bit mismatch");
    println!("  fast/scalar outputs bit-identical for all three kernels");
}
