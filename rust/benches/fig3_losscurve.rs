//! Fig 3 (short form): training-loss curves of the three rules on the tiny
//! LM bundle — checks the paper's shape (CDP-v1 higher early, all three
//! converging together).  `examples/train_lm.rs` is the full-scale run.
//! Needs the transformer family, i.e. the `xla` feature + `make
//! artifacts`; the native build prints a skip note.

mod harness;

#[cfg(not(feature = "xla"))]
fn main() {
    let _b = harness::Bench::new("fig3_losscurve");
    println!(
        "SKIP: fig3 trains the tiny transformer bundle, which needs the \
         `xla` feature (cargo bench --features xla) + `make artifacts`"
    );
}

#[cfg(feature = "xla")]
fn main() {
    use cyclic_dp::coordinator::single::RefTrainer;
    use cyclic_dp::metrics::Series;
    use cyclic_dp::model::artifacts_root;
    use cyclic_dp::parallel::rule_by_name;
    use cyclic_dp::runtime::BundleRuntime;

    let b = harness::Bench::new("fig3_losscurve");
    if !harness::have_bundle("tiny") {
        return;
    }
    let rt = BundleRuntime::load(&artifacts_root().join("tiny")).unwrap();
    let steps = 30;

    b.section(&format!("tiny LM bundle, {steps} steps"));
    let mut curves: Vec<(&str, Series)> = Vec::new();
    for rule_name in ["dp", "cdp_v1", "cdp_v2"] {
        let rule = rule_by_name(rule_name).unwrap();
        let mut t = RefTrainer::new(&rt, rule).unwrap();
        let mut s = Series::new(rule_name);
        for step in 0..steps {
            let log = t.step().unwrap();
            s.push(step as f64, log.loss);
        }
        curves.push((rule_name, s));
    }

    // render a compact ascii table, smoothed like the paper (window 5)
    println!("{:>5} {:>9} {:>9} {:>9}", "step", "dp", "cdp_v1", "cdp_v2");
    let smoothed: Vec<Vec<(f64, f64)>> =
        curves.iter().map(|(_, s)| s.smoothed(5)).collect();
    for i in (0..steps).step_by(3) {
        println!(
            "{:>5} {:>9.4} {:>9.4} {:>9.4}",
            i, smoothed[0][i].1, smoothed[1][i].1, smoothed[2][i].1
        );
    }

    let early = 5usize;
    println!(
        "\nearly (step {early}) smoothed: dp {:.4} | v1 {:.4} | v2 {:.4}  \
         (paper: v1 visibly higher early)",
        smoothed[0][early].1, smoothed[1][early].1, smoothed[2][early].1
    );
    let last = steps - 1;
    println!(
        "final: dp {:.4} | v1 {:.4} | v2 {:.4}  (paper: all converge together)",
        smoothed[0][last].1, smoothed[1][last].1, smoothed[2][last].1
    );
}
