//! Auto-planner bench (DESIGN-PERF.md §Auto-planner): profile two
//! contrasting synthetic shapes, run the planner's search, then *execute*
//! the top-ranked candidates and compare predicted against measured step
//! time.  The headline counter is `planner_pick_regret` — how much slower
//! the planner's pick is than the best candidate we actually measured
//! (0.0 = the planner picked the true winner).  The regret tolerance is
//! soft by default and hard under `CDP_BENCH_STRICT=1`; results go to
//! `BENCH_plan.json`, SHA-stamped, for the CI regression gate.

mod harness;

use std::collections::BTreeSet;
use std::sync::Arc;

use cyclic_dp::coordinator::{execute_plan, SharedBackend};
use cyclic_dp::plan::{search, Candidate, SearchSpace};
use cyclic_dp::profile::{ProfileOpts, StageProfiler};
use cyclic_dp::runtime::{NativeBackend, NativeMlpConfig};

/// Regret tolerance the ISSUE acceptance pins: the pick must be within
/// 15% of the best measured candidate.
const REGRET_TOL: f64 = 0.15;

/// Candidates executed per shape (deduped by trainer/variant/rule/k —
/// bucket size and precision variants of the same coordinator measure
/// nearly identically and would only pad the bench).
const MAX_EXEC: usize = 5;

fn main() {
    // Pool spawn + kernel-mode resolution before any timed window.
    cyclic_dp::util::par::warm();
    std::hint::black_box(cyclic_dp::tensor::ops::kernel_mode());

    let b = harness::Bench::new("plan");
    let mut stats: Vec<harness::Stat> = Vec::new();
    let mut counters: Vec<(String, f64)> = Vec::new();
    let strict = std::env::var("CDP_BENCH_STRICT").as_deref() == Ok("1");
    let mut worst_regret = 0.0f64;

    for (shape, cfg) in [
        ("deep_narrow", NativeMlpConfig::deep_narrow()),
        ("shallow_wide", NativeMlpConfig::shallow_wide()),
    ] {
        b.section(&format!("{shape}: profile, search, execute top plans"));

        let profiler = StageProfiler::new(ProfileOpts::default());
        let profile = profiler.profile_native(&cfg).expect("profile");
        let budget = 4u64 << 30; // generous: rank purely by predicted time
        let space = SearchSpace::for_profile(&profile);
        let ranked = search(&profile, budget, &space).expect("search");
        println!(
            "  {} candidates, pick: {}",
            ranked.candidates.len(),
            ranked.winner().plan.label()
        );

        // Dedupe to one candidate per coordinator configuration; the
        // planner's pick is candidate 0, so it always executes.
        let mut seen: BTreeSet<String> = BTreeSet::new();
        let exec_cands: Vec<&Candidate> = ranked
            .candidates
            .iter()
            .filter(|c| c.feasible)
            .filter(|c| {
                let p = &c.plan;
                seen.insert(format!(
                    "{}/{}/{}/k{}",
                    p.trainer.name(),
                    p.variant.name(),
                    p.rule.name(),
                    p.n_stages
                ))
            })
            .take(MAX_EXEC)
            .collect();

        let base = NativeBackend::synthetic(cfg);
        let mut best_meas = f64::INFINITY;
        let mut pick_meas = f64::INFINITY;
        for (i, c) in exec_cands.iter().enumerate() {
            let plan = &c.plan;
            let rt = base
                .repartitioned(plan.n_stages as usize)
                .expect("divisor stage count")
                .with_precision(plan.precision);
            let n_mb = rt.manifest.n_microbatches.max(1) as f64;
            let shared = SharedBackend(Arc::new(rt));
            let label = format!("{shape} {}", plan.label());
            let st = b.time_stat(&label, 1, 3, || {
                std::hint::black_box(
                    execute_plan(shared.clone(), plan, 1).expect("plan executes"),
                );
            });
            // Normalize to per-micro-batch so stage counts with different
            // square-schedule widths compare on equal work.
            let meas_per_mb = st.mean_ns / n_mb;
            println!(
                "    predicted {:9.1} us/mb | measured {:9.1} us/mb",
                plan.predicted_step_ns / 1e3,
                meas_per_mb / 1e3
            );
            counters.push((format!("pred_us::{label}"), plan.predicted_step_ns / 1e3));
            counters.push((format!("meas_us::{label}"), meas_per_mb / 1e3));
            stats.push(st);
            best_meas = best_meas.min(meas_per_mb);
            if i == 0 {
                pick_meas = meas_per_mb;
            }
        }

        let regret = pick_meas / best_meas - 1.0;
        println!(
            "  {shape} planner-pick regret: {:.1}% (tolerance {:.0}%)",
            regret * 100.0,
            REGRET_TOL * 100.0
        );
        counters.push((format!("plan_regret_{shape}"), regret));
        worst_regret = worst_regret.max(regret);
    }

    counters.push(("planner_pick_regret".into(), worst_regret));
    counters.push(("planner_regret_tolerance".into(), REGRET_TOL));
    if worst_regret > REGRET_TOL {
        let msg = format!(
            "planner pick regret {:.1}% exceeds {:.0}% tolerance",
            worst_regret * 100.0,
            REGRET_TOL * 100.0
        );
        if strict {
            panic!("{msg} (CDP_BENCH_STRICT=1)");
        }
        println!("  WARN: {msg} — soft outside CDP_BENCH_STRICT=1");
    }

    harness::write_json("BENCH_plan.json", "plan", &stats, &counters);
}
