//! Fig 1: execution timelines for DP, CDP-v1/v2 (N = 3, as in the paper),
//! with the properties the figure illustrates: barrier positions,
//! per-step activation totals, hand-off events; plus schedule-generation
//! throughput for large N.

mod harness;

use cyclic_dp::parallel::Schedule;

fn main() {
    let b = harness::Bench::new("fig1_timeline");

    b.section("Fig 1a — DP, N=3");
    let dp = Schedule::dp(3, 12);
    print!("{}", dp.render(12));
    println!("barriers: {:?}", dp.barrier_steps(12));

    b.section("Fig 1b/c — CDP, N=3 (delay 2(i-1))");
    let cdp = Schedule::cyclic(3, 14);
    print!("{}", cdp.render(14));
    for k in 5..11 {
        let h = cdp.handoffs_after(k);
        println!("t={k}: hand-offs {h:?}");
    }

    b.section("activation totals per time step (N=3)");
    print!("DP : ");
    (0..12).for_each(|k| print!("{:>3}", dp.total_stashes_after(k)));
    print!("\nCDP: ");
    (0..12).for_each(|k| print!("{:>3}", cdp.total_stashes_after(k)));
    println!();
    let (dpk, dpm) = dp.stash_stats();
    let (ck, cm) = cdp.stash_stats();
    println!("DP peak {dpk} (mean {dpm:.1}) | CDP peak {ck} (mean {cm:.1})");

    b.section("schedule generation throughput");
    for n in [8usize, 64, 256] {
        b.time(&format!("cyclic schedule N={n}, horizon=8N"), 2, 20, || {
            let s = Schedule::cyclic(n, 8 * n);
            std::hint::black_box(s.stash_stats());
        });
    }
}
