//! Fig 2: per-scheme device / memory / communication schematics ± CDP
//! (N = 3 as the paper draws them, plus N = 4, 8 scaling), from the
//! discrete-time simulation.

mod harness;

use cyclic_dp::sim::{schemes, Scheme, SymbolicCosts};

fn main() {
    let b = harness::Bench::new("fig2_schemes");
    let c = SymbolicCosts {
        psi_p: 4_000_000,
        b_psi_a: 8_000_000,
        b_psi_a_int: 400_000,
    };

    for n in [3usize, 4, 8] {
        b.section(&format!("N = {n}"));
        for s in Scheme::all() {
            println!("{}", schemes::render_scheme(s, n, c));
        }
        // the paper's headline deltas
        let mp = schemes::simulate_scheme(Scheme::DpMp, n, c);
        let mpc = schemes::simulate_scheme(Scheme::DpMpCdp, n, c);
        println!(
            "→ MP devices: {} → {} ({}% saved), idle {:.0}% → {:.0}%",
            mp.n_devices,
            mpc.n_devices,
            100 * (mp.n_devices - mpc.n_devices) / mp.n_devices,
            mp.idle_fraction * 100.0,
            mpc.idle_fraction * 100.0
        );
        let zb = schemes::simulate_scheme(Scheme::ZeroDp, n, c);
        let zc = schemes::simulate_scheme(Scheme::ZeroCdp, n, c);
        println!(
            "→ ZeRO msgs/boundary: {} → {}",
            zb.max_comm_events_per_boundary, zc.max_comm_events_per_boundary
        );
    }

    b.section("simulation throughput");
    b.time("simulate all 9 schemes, N=64", 2, 50, || {
        for s in Scheme::all() {
            std::hint::black_box(schemes::simulate_scheme(s, 64, c));
        }
    });
}
