//! Hot-path microbenchmarks (DESIGN.md §Perf-L3 / DESIGN-PERF.md): the
//! per-step cost decomposition of the coordinator — execution, literal
//! conversion, gradient reduction, SGD — plus fabric primitives, and the
//! arena-vs-seed comparisons for the flat-state refactor:
//!
//! - gradient reduction: per-tensor `Vec<Tensor>` accumulation + flatten
//!   (the seed representation) vs one fused pass over a flat arena, with
//!   a steady-state allocation count (must be zero for the arena path);
//! - collectives: pooled zero-copy payloads vs per-send `Vec` clones;
//! - ring parameter hand-off: per-hop buffer clone vs `Arc` handle clone.
//!
//! Results are printed and written to `BENCH_hotpath.json` (artifact-free
//! portions always run; bundle sections require `make artifacts`).

mod harness;

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use cyclic_dp::comm::collectives::{allreduce_mean, ring_allreduce};
use cyclic_dp::comm::{tags, Endpoint, Fabric};
use cyclic_dp::coordinator::single::RefTrainer;
use cyclic_dp::coordinator::{multi, SharedRuntime};
use cyclic_dp::data::DataSource;
use cyclic_dp::model::artifacts_root;
use cyclic_dp::parallel::arena::ArenaLayout;
use cyclic_dp::parallel::{GradBuffer, Rule};
use cyclic_dp::runtime::{tensor_to_literal, BundleRuntime};
use cyclic_dp::tensor::ops::{add_into, axpy, reduce_rows};
use cyclic_dp::tensor::Tensor;

// ---- allocation accounting ------------------------------------------------
// Counts every heap allocation so the bench can prove the arena reduction
// loop is allocation-free in steady state.

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// Synthetic model used by the artifact-free comparisons: 8 stages × 8
/// tensors × 16384 elems ≈ 1M params.
const N_STAGES: usize = 8;
const T_PER_STAGE: usize = 8;
const T_ELEMS: usize = 16_384;
const N_MB: usize = 4;

fn synth_shapes() -> Vec<Vec<Vec<usize>>> {
    (0..N_STAGES)
        .map(|_| (0..T_PER_STAGE).map(|_| vec![T_ELEMS]).collect())
        .collect()
}

fn main() {
    let b = harness::Bench::new("hotpath");
    let mut stats: Vec<harness::Stat> = Vec::new();
    let mut counters: Vec<(String, f64)> = Vec::new();

    b.section("host reduction primitives (1M f32)");
    let x: Vec<f32> = (0..1_000_000).map(|i| i as f32 * 1e-6).collect();
    let mut acc = x.clone();
    stats.push(b.time_stat("add_into 1M f32", 3, 50, || {
        add_into(&mut acc, &x);
    }));
    stats.push(b.time_stat("axpy 1M f32", 3, 50, || {
        axpy(&mut acc, 0.5, &x);
    }));
    let rows: Vec<&[f32]> = vec![&x, &x, &x, &x];
    stats.push(b.time_stat("reduce_rows 4×1M f32 (chunked)", 3, 20, || {
        std::hint::black_box(reduce_rows(&rows));
    }));

    // ---- arena vs seed: gradient reduction --------------------------------
    b.section("gradient reduction: seed per-tensor vs flat arena (~1M params)");
    let shapes = synth_shapes();
    let layout = ArenaLayout::from_stage_shapes(&shapes);
    let grad_row: Vec<f32> = (0..layout.total_len).map(|i| (i as f32).sin()).collect();

    // seed representation: nested Vec<Vec<Tensor>> sums, per-tensor
    // accumulation, then a flatten (fresh Vec) per stage as the seed's ring
    // send path did
    let grad_tensors: Vec<Vec<Tensor>> = layout.unflatten(&grad_row);
    let mut seed_sums: Vec<Vec<Tensor>> = shapes
        .iter()
        .map(|st| st.iter().map(|s| Tensor::zeros(s.clone())).collect())
        .collect();
    stats.push(b.time_stat("reduce seed: per-tensor + flatten", 2, 20, || {
        for st in seed_sums.iter_mut() {
            for t in st.iter_mut() {
                t.fill(0.0);
            }
        }
        for _mb in 0..N_MB {
            for (ss, gs) in seed_sums.iter_mut().zip(&grad_tensors) {
                for (s, g) in ss.iter_mut().zip(gs) {
                    s.add_assign(g);
                }
            }
        }
        // the seed's hand-off: flatten each stage into a fresh Vec
        for st in &seed_sums {
            let flat: Vec<f32> =
                st.iter().flat_map(|t| t.data.iter().copied()).collect();
            std::hint::black_box(flat);
        }
    }));

    // arena representation: fused flat accumulation, zero allocations
    let mut gbuf = GradBuffer::new(layout.clone(), N_MB);
    let arena_step = |gbuf: &mut GradBuffer| {
        for mb in 1..=N_MB {
            gbuf.add_all_flat(mb, &grad_row);
        }
        gbuf.average();
        for j in 0..N_STAGES {
            std::hint::black_box(gbuf.stage(j));
        }
        gbuf.reset();
    };
    stats.push(b.time_stat("reduce arena: fused flat", 2, 20, || {
        arena_step(&mut gbuf);
    }));
    // steady-state allocation proof: after warmup, N full reduction loops
    // must not allocate at all
    arena_step(&mut gbuf);
    let a0 = allocs();
    for _ in 0..10 {
        arena_step(&mut gbuf);
    }
    let steady_allocs = allocs() - a0;
    println!("  grad-reduction steady-state allocations      {steady_allocs} (want 0)");
    counters.push(("grad_reduction_steady_state_allocs".into(), steady_allocs as f64));

    // ---- fabric collectives ----------------------------------------------
    b.section("fabric collectives (4 workers, 1M f32, pooled)");
    for (label, ring) in [
        ("flat allreduce (pooled)", false),
        ("ring allreduce (pooled)", true),
    ] {
        stats.push(b.time_stat(label, 1, 5, || {
            let (eps, _) = Fabric::new(4);
            let handles: Vec<_> = eps
                .into_iter()
                .map(|mut ep| {
                    std::thread::spawn(move || {
                        let mut data = vec![1.0f32; 1_000_000];
                        for step in 0..4u64 {
                            if ring {
                                ring_allreduce(&mut ep, step, &mut data);
                            } else {
                                allreduce_mean(&mut ep, step, &mut data);
                            }
                        }
                    })
                })
                .collect();
            handles.into_iter().for_each(|h| h.join().unwrap());
        }));
    }
    // seed-style comparison: every send clones into a fresh Vec
    stats.push(b.time_stat("ring allreduce (seed: clone per send)", 1, 5, || {
        let (eps, _) = Fabric::new(4);
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut ep| {
                std::thread::spawn(move || {
                    let mut data = vec![1.0f32; 1_000_000];
                    for step in 0..4u64 {
                        ring_allreduce_unpooled(&mut ep, step, &mut data);
                    }
                })
            })
            .collect();
        handles.into_iter().for_each(|h| h.join().unwrap());
    }));
    // pool effectiveness over a long-lived fabric
    {
        let (eps, _) = Fabric::new(4);
        let pool = eps[0].pool().clone();
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut ep| {
                std::thread::spawn(move || {
                    let mut data = vec![1.0f32; 100_000];
                    for step in 0..16u64 {
                        ring_allreduce(&mut ep, step, &mut data);
                    }
                })
            })
            .collect();
        handles.into_iter().for_each(|h| h.join().unwrap());
        println!(
            "  buffer pool over 16 ring rounds               recycled {} | allocated {}",
            pool.recycled(),
            pool.allocated()
        );
        counters.push(("ring16_pool_recycled".into(), pool.recycled() as f64));
        counters.push(("ring16_pool_allocated".into(), pool.allocated() as f64));
    }

    // ---- arena vs seed: ring parameter hand-off ---------------------------
    b.section("ring param hand-off (4 hops, 1M f32)");
    let params_row: Vec<f32> = vec![0.5f32; 1_000_000];
    stats.push(b.time_stat("hand-off seed: clone per hop", 1, 10, || {
        run_handoff(&params_row, false);
    }));
    stats.push(b.time_stat("hand-off arena: payload handle", 1, 10, || {
        run_handoff(&params_row, true);
    }));

    let have_mlp = harness::have_bundle("mlp");
    if !have_mlp {
        harness::write_json("BENCH_hotpath.json", "hotpath", &stats, &counters);
        return;
    }
    let rt = BundleRuntime::load(&artifacts_root().join("mlp")).unwrap();

    b.section("literal conversion (mlp stage-1 params)");
    let params = rt.init_params().unwrap();
    stats.push(b.time_stat("tensor_to_literal stage 1 (4 tensors)", 3, 100, || {
        for t in &params[1] {
            std::hint::black_box(tensor_to_literal(t).unwrap());
        }
    }));
    let flat = rt.init_params_flat().unwrap();
    let mlp_layout = ArenaLayout::from_manifest(&rt.manifest);
    stats.push(b.time_stat("param_literals_flat stage 1", 3, 100, || {
        std::hint::black_box(
            rt.param_literals_flat(1, &flat[mlp_layout.stage_range(1)]).unwrap(),
        );
    }));

    b.section("executable dispatch (mlp bundle)");
    let data = DataSource::from_manifest(&rt.manifest);
    let mb = data.microbatch(0, 0);
    let x = match &mb {
        cyclic_dp::data::MicroBatch::Class { x, .. } => x.clone(),
        _ => unreachable!(),
    };
    let hx = cyclic_dp::tensor::HostTensor::F32(x);
    stats.push(b.time_stat("stage_fwd(1)", 3, 50, || {
        let y = rt.stage_fwd(0, &params[0], &hx).unwrap();
        std::hint::black_box(y);
    }));

    b.section("end-to-end training step");
    let mut t = RefTrainer::new(&rt, Rule::CdpV2).unwrap();
    stats.push(b.time_stat("RefTrainer::step (cdp_v2, mlp)", 2, 10, || {
        t.step().unwrap();
    }));

    b.section("multi-worker step (4 threads)");
    let shared = SharedRuntime(Arc::new(rt));
    stats.push(b.time_stat("multi ring 2 steps (cdp_v2)", 1, 3, || {
        std::hint::black_box(
            multi::train(shared.clone(), Rule::CdpV2, multi::CommPattern::Ring, 2)
                .unwrap(),
        );
    }));
    stats.push(b.time_stat("multi barrier 2 steps (dp)", 1, 3, || {
        std::hint::black_box(
            multi::train(shared.clone(), Rule::Dp, multi::CommPattern::Barrier, 2)
                .unwrap(),
        );
    }));

    let mut sgd_params = shared.init_params().unwrap();
    let mut moms = shared.zero_like_params();
    let grads = shared.zero_like_params();
    b.section("optimizer");
    stats.push(b.time_stat("sgd_update all stages (per-tensor)", 2, 20, || {
        for j in 0..shared.manifest.n_stages {
            shared
                .sgd_update(j, &mut sgd_params[j], &mut moms[j], &grads[j], 0.01)
                .unwrap();
        }
    }));
    let mut flat_p = shared.init_params_flat().unwrap();
    let mut flat_m = mlp_layout.zeros();
    let mut flat_o = mlp_layout.zeros();
    let flat_g = mlp_layout.zeros();
    stats.push(b.time_stat("sgd_update_flat all stages (arena)", 2, 20, || {
        for j in 0..shared.manifest.n_stages {
            let r = mlp_layout.stage_range(j);
            shared
                .sgd_update_flat(
                    j,
                    &flat_p[r.clone()],
                    &mut flat_m[r.clone()],
                    &flat_g[r.clone()],
                    0.01,
                    &mut flat_o[r],
                )
                .unwrap();
        }
        std::mem::swap(&mut flat_p, &mut flat_o);
    }));

    harness::write_json("BENCH_hotpath.json", "hotpath", &stats, &counters);
}

/// The seed fabric's ring all-reduce: identical schedule, but every send
/// clones the chunk into a fresh `Vec` (what `Endpoint::send` did before
/// payloads were pooled).  Kept here as the A/B baseline.
fn ring_allreduce_unpooled(ep: &mut Endpoint, step: u64, data: &mut [f32]) {
    let n = ep.n;
    if n == 1 {
        return;
    }
    let len = data.len();
    let chunk = |c: usize| -> std::ops::Range<usize> {
        let base = len / n;
        let rem = len % n;
        let start = c * base + c.min(rem);
        let size = base + usize::from(c < rem);
        start..start + size
    };
    let me = ep.id;
    for p in 0..n - 1 {
        let send_c = (me + n - p) % n;
        let recv_c = (me + n - p - 1) % n;
        ep.send(ep.right(), tags::ring(step, p), data[chunk(send_c)].to_vec());
        let part = ep.recv(ep.left(), tags::ring(step, p));
        add_into(&mut data[chunk(recv_c)], &part);
    }
    for p in 0..n - 1 {
        let send_c = (me + 1 + n - p) % n;
        let recv_c = (me + n - p) % n;
        ep.send(
            ep.right(),
            tags::ring(step, n + p),
            data[chunk(send_c)].to_vec(),
        );
        let part = ep.recv(ep.left(), tags::ring(step, n + p));
        data[chunk(recv_c)].copy_from_slice(&part);
    }
}

/// Parameter hand-off around a 4-ring: rank 0 produces the fresh
/// parameters, every other rank forwards them on.  `zero_copy` forwards
/// the received payload handle; otherwise each hop clones into a fresh
/// `Vec` (the seed behavior).
fn run_handoff(params: &[f32], zero_copy: bool) {
    let (eps, _) = Fabric::new(4);
    let src = params.to_vec();
    let handles: Vec<_> = eps
        .into_iter()
        .map(|mut ep| {
            let src = src.clone();
            std::thread::spawn(move || {
                let n = ep.n;
                if ep.id == 0 {
                    ep.send_copy(1, tags::param(0, 0), &src);
                } else {
                    let got = ep.recv(ep.left(), tags::param(0, 0));
                    if ep.id + 1 < n {
                        if zero_copy {
                            ep.send(ep.id + 1, tags::param(0, 0), got.clone());
                        } else {
                            ep.send(ep.id + 1, tags::param(0, 0), got.to_vec());
                        }
                    }
                    std::hint::black_box(got[0]);
                }
            })
        })
        .collect();
    handles.into_iter().for_each(|h| h.join().unwrap());
}
