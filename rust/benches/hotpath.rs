//! Hot-path microbenchmarks (DESIGN.md §Perf-L3): the per-step cost
//! decomposition of the coordinator — execution, literal conversion,
//! gradient reduction, SGD — plus fabric primitives.  This is the bench
//! the §Perf iteration log in EXPERIMENTS.md is measured with.

mod harness;

use std::sync::Arc;

use cyclic_dp::comm::collectives::{allreduce_mean, ring_allreduce};
use cyclic_dp::comm::Fabric;
use cyclic_dp::coordinator::single::RefTrainer;
use cyclic_dp::coordinator::{multi, SharedRuntime};
use cyclic_dp::data::DataSource;
use cyclic_dp::model::artifacts_root;
use cyclic_dp::parallel::Rule;
use cyclic_dp::runtime::{tensor_to_literal, BundleRuntime};
use cyclic_dp::tensor::ops::{add_into, reduce_rows};
use cyclic_dp::tensor::Tensor;

fn main() {
    let b = harness::Bench::new("hotpath");

    b.section("host reduction primitives (1M f32)");
    let x: Vec<f32> = (0..1_000_000).map(|i| i as f32 * 1e-6).collect();
    let mut acc = x.clone();
    b.time("add_into 1M f32", 3, 50, || {
        add_into(&mut acc, &x);
    });
    let rows: Vec<&[f32]> = vec![&x, &x, &x, &x];
    b.time("reduce_rows 4×1M f32", 3, 20, || {
        std::hint::black_box(reduce_rows(&rows));
    });

    b.section("fabric collectives (4 workers, 1M f32)");
    for (label, ring) in [("flat allreduce", false), ("ring allreduce", true)] {
        b.time(label, 1, 5, || {
            let (eps, _) = Fabric::new(4);
            let handles: Vec<_> = eps
                .into_iter()
                .map(|mut ep| {
                    std::thread::spawn(move || {
                        let mut data = vec![1.0f32; 1_000_000];
                        if ring {
                            ring_allreduce(&mut ep, 0, &mut data);
                        } else {
                            allreduce_mean(&mut ep, 0, &mut data);
                        }
                    })
                })
                .collect();
            handles.into_iter().for_each(|h| h.join().unwrap());
        });
    }

    if !harness::have_bundle("mlp") {
        return;
    }
    let rt = BundleRuntime::load(&artifacts_root().join("mlp")).unwrap();

    b.section("literal conversion (mlp stage-1 params)");
    let params = rt.init_params().unwrap();
    b.time("tensor_to_literal stage 1 (4 tensors)", 3, 100, || {
        for t in &params[1] {
            std::hint::black_box(tensor_to_literal(t).unwrap());
        }
    });

    b.section("executable dispatch (mlp bundle)");
    let data = DataSource::from_manifest(&rt.manifest);
    let mb = data.microbatch(0, 0);
    let x = match &mb {
        cyclic_dp::data::MicroBatch::Class { x, .. } => x.clone(),
        _ => unreachable!(),
    };
    let hx = cyclic_dp::tensor::HostTensor::F32(x);
    b.time("stage_fwd(1)", 3, 50, || {
        let y = rt.stage_fwd(0, &params[0], &hx).unwrap();
        std::hint::black_box(y);
    });

    b.section("end-to-end training step");
    let mut t = RefTrainer::new(&rt, Rule::CdpV2).unwrap();
    b.time("RefTrainer::step (cdp_v2, mlp)", 2, 10, || {
        t.step().unwrap();
    });

    b.section("multi-worker step (4 threads)");
    let shared = SharedRuntime(Arc::new(rt));
    b.time("multi ring 2 steps (cdp_v2)", 1, 3, || {
        std::hint::black_box(
            multi::train(shared.clone(), Rule::CdpV2, multi::CommPattern::Ring, 2)
                .unwrap(),
        );
    });
    b.time("multi barrier 2 steps (dp)", 1, 3, || {
        std::hint::black_box(
            multi::train(shared.clone(), Rule::Dp, multi::CommPattern::Barrier, 2)
                .unwrap(),
        );
    });

    let mut sgd_params = shared.init_params().unwrap();
    let mut moms = shared.zero_like_params();
    let grads = shared.zero_like_params();
    b.section("optimizer");
    b.time("sgd_update all stages", 2, 20, || {
        for j in 0..shared.manifest.n_stages {
            shared
                .sgd_update(j, &mut sgd_params[j], &mut moms[j], &grads[j], 0.01)
                .unwrap();
        }
    });
}
