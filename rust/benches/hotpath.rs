//! Hot-path microbenchmarks (DESIGN.md §Perf-L3 / DESIGN-PERF.md): the
//! per-step cost decomposition of the coordinator — execution, literal
//! conversion, gradient reduction, SGD — plus fabric primitives, and the
//! arena-vs-seed comparisons for the flat-state refactor:
//!
//! - gradient reduction: per-tensor `Vec<Tensor>` accumulation + flatten
//!   (the seed representation) vs one fused pass over a flat arena, with
//!   a steady-state allocation count (must be zero for the arena path);
//! - collectives: pooled zero-copy payloads vs per-send `Vec` clones;
//! - ring parameter hand-off: per-hop buffer clone vs `Arc` handle clone.
//!
//! Results are printed and written to `BENCH_hotpath.json` (artifact-free
//! portions always run; bundle sections require `make artifacts`).

mod harness;

use std::sync::Arc;

use cyclic_dp::comm::bucketed::BucketedReducer;
use cyclic_dp::comm::collectives::{allreduce_mean, ring_allreduce};
use cyclic_dp::comm::{tags, CommStats, Endpoint, EventKind, Fabric, RingView};
use cyclic_dp::coordinator::single::RefTrainer;
use cyclic_dp::coordinator::{multi, SharedBackend};
use cyclic_dp::parallel::arena::ArenaLayout;
use cyclic_dp::parallel::{GradBuffer, Rule};
use cyclic_dp::runtime::{Backend, NativeBackend, NativeMlpConfig};
use cyclic_dp::tensor::ops::{
    add_into, add_scale_into, axpy, reduce_rows, scale, set_kernel_mode, KernelMode,
};
use cyclic_dp::tensor::Tensor;
use cyclic_dp::testing::instrument::{
    self, alloc_count, CountingAlloc,
};

// Allocation accounting: the counting allocator lives in
// `testing::instrument` (shared with the wire bench and the profiler);
// only the `#[global_allocator]` declaration must sit in the binary.
#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    alloc_count()
}

/// Synthetic model used by the artifact-free comparisons: 8 stages × 8
/// tensors × 16384 elems ≈ 1M params.
const N_STAGES: usize = 8;
const T_PER_STAGE: usize = 8;
const T_ELEMS: usize = 16_384;
const N_MB: usize = 4;

fn synth_shapes() -> Vec<Vec<Vec<usize>>> {
    (0..N_STAGES)
        .map(|_| (0..T_PER_STAGE).map(|_| vec![T_ELEMS]).collect())
        .collect()
}

fn main() {
    // One-time setup excluded from every counted allocation window
    // (DESIGN-PERF.md §Zero-alloc windowing): spawn the kernel worker
    // pool and resolve the kernel dispatch mode *before* any window
    // opens, so thread stacks, the leaked pool state and the env lookup
    // never land inside a steady-state count.
    cyclic_dp::util::par::warm();
    std::hint::black_box(cyclic_dp::tensor::ops::kernel_mode());

    let b = harness::Bench::new("hotpath");
    let mut stats: Vec<harness::Stat> = Vec::new();
    let mut counters: Vec<(String, f64)> = Vec::new();

    b.section("host reduction primitives (1M f32)");
    let x: Vec<f32> = (0..1_000_000).map(|i| i as f32 * 1e-6).collect();
    let mut acc = x.clone();
    stats.push(b.time_stat("add_into 1M f32", 3, 50, || {
        add_into(&mut acc, &x);
    }));
    stats.push(b.time_stat("axpy 1M f32", 3, 50, || {
        axpy(&mut acc, 0.5, &x);
    }));
    let rows: Vec<&[f32]> = vec![&x, &x, &x, &x];
    stats.push(b.time_stat("reduce_rows 4×1M f32 (chunked)", 3, 20, || {
        std::hint::black_box(reduce_rows(&rows));
    }));

    // ---- arena vs seed: gradient reduction --------------------------------
    b.section("gradient reduction: seed per-tensor vs flat arena (~1M params)");
    let shapes = synth_shapes();
    let layout = ArenaLayout::from_stage_shapes(&shapes);
    let grad_row: Vec<f32> = (0..layout.total_len).map(|i| (i as f32).sin()).collect();

    // seed representation: nested Vec<Vec<Tensor>> sums, per-tensor
    // accumulation, then a flatten (fresh Vec) per stage as the seed's ring
    // send path did
    let grad_tensors: Vec<Vec<Tensor>> = layout.unflatten(&grad_row);
    let mut seed_sums: Vec<Vec<Tensor>> = shapes
        .iter()
        .map(|st| st.iter().map(|s| Tensor::zeros(s.clone())).collect())
        .collect();
    stats.push(b.time_stat("reduce seed: per-tensor + flatten", 2, 20, || {
        for st in seed_sums.iter_mut() {
            for t in st.iter_mut() {
                t.fill(0.0);
            }
        }
        for _mb in 0..N_MB {
            for (ss, gs) in seed_sums.iter_mut().zip(&grad_tensors) {
                for (s, g) in ss.iter_mut().zip(gs) {
                    s.add_assign(g);
                }
            }
        }
        // the seed's hand-off: flatten each stage into a fresh Vec
        for st in &seed_sums {
            let flat: Vec<f32> =
                st.iter().flat_map(|t| t.data.iter().copied()).collect();
            std::hint::black_box(flat);
        }
    }));

    // arena representation: fused flat accumulation, zero allocations
    let mut gbuf = GradBuffer::new(layout.clone(), N_MB);
    let arena_step = |gbuf: &mut GradBuffer| {
        for mb in 1..=N_MB {
            gbuf.add_all_flat(mb, &grad_row);
        }
        gbuf.average();
        for j in 0..N_STAGES {
            std::hint::black_box(gbuf.stage(j));
        }
        gbuf.reset();
    };
    stats.push(b.time_stat("reduce arena: fused flat", 2, 20, || {
        arena_step(&mut gbuf);
    }));
    // steady-state allocation proof: after warmup, N full reduction loops
    // must not allocate at all
    arena_step(&mut gbuf);
    let a0 = allocs();
    for _ in 0..10 {
        arena_step(&mut gbuf);
    }
    let steady_allocs = allocs() - a0;
    println!("  grad-reduction steady-state allocations      {steady_allocs} (want 0)");
    counters.push(("grad_reduction_steady_state_allocs".into(), steady_allocs as f64));
    assert_eq!(steady_allocs, 0, "arena reduction loop must not allocate");

    // ...extended to the full multi-trainer owner step machinery: the
    // bucketed-ring owner's per-stage work — bucket iteration, fused
    // assemble-and-average per bucket (`add_scale_into`), mb-ordered
    // GradBuffer accumulation, average, per-stage reads, reset.  The
    // only per-step heap traffic a real multi step adds beyond this is
    // the fabric's channel nodes (pooled payload buffers recycle, see
    // the plateau check below) and the XLA FFI itself.
    let mut avg_run = layout.zeros();
    let owner_step = |gbuf: &mut GradBuffer, avg: &mut [f32]| {
        for mb in 1..=N_MB {
            gbuf.add_all_flat(mb, &grad_row);
        }
        gbuf.average();
        for j in (0..N_STAGES).rev() {
            let base = layout.stage_range(j).start;
            for bk in layout.stage_buckets(j, 4096) {
                let r = base + bk.start..base + bk.end;
                add_scale_into(
                    &mut avg[r.clone()],
                    &grad_row[r.clone()],
                    &grad_row[r],
                    1.0 / N_MB as f32,
                );
            }
            std::hint::black_box(gbuf.stage(j));
        }
        gbuf.reset();
    };
    owner_step(&mut gbuf, &mut avg_run[..]);
    let a0 = allocs();
    for _ in 0..10 {
        owner_step(&mut gbuf, &mut avg_run[..]);
    }
    let owner_allocs = allocs() - a0;
    println!("  bucketed owner-step steady-state allocations {owner_allocs} (want 0)");
    counters.push(("bucketed_owner_step_steady_state_allocs".into(), owner_allocs as f64));
    assert_eq!(owner_allocs, 0, "bucketed owner step must not allocate");

    // ---- fabric collectives ----------------------------------------------
    b.section("fabric collectives (4 workers, 1M f32, pooled)");
    for (label, ring) in [
        ("flat allreduce (pooled)", false),
        ("ring allreduce (pooled)", true),
    ] {
        stats.push(b.time_stat(label, 1, 5, || {
            let (eps, _) = Fabric::new(4);
            let handles: Vec<_> = eps
                .into_iter()
                .map(|mut ep| {
                    std::thread::spawn(move || {
                        let mut data = vec![1.0f32; 1_000_000];
                        for step in 0..4u64 {
                            if ring {
                                ring_allreduce(&mut ep, step, &mut data).unwrap();
                            } else {
                                allreduce_mean(&mut ep, step, &mut data).unwrap();
                            }
                        }
                    })
                })
                .collect();
            handles.into_iter().for_each(|h| h.join().unwrap());
        }));
    }
    // seed-style comparison: every send clones into a fresh Vec
    stats.push(b.time_stat("ring allreduce (seed: clone per send)", 1, 5, || {
        let (eps, _) = Fabric::new(4);
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut ep| {
                std::thread::spawn(move || {
                    let mut data = vec![1.0f32; 1_000_000];
                    for step in 0..4u64 {
                        ring_allreduce_unpooled(&mut ep, step, &mut data);
                    }
                })
            })
            .collect();
        handles.into_iter().for_each(|h| h.join().unwrap());
    }));
    // pool effectiveness over a long-lived fabric
    {
        let (eps, _) = Fabric::new(4);
        let pool = eps[0].pool().clone();
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut ep| {
                std::thread::spawn(move || {
                    let mut data = vec![1.0f32; 100_000];
                    for step in 0..16u64 {
                        ring_allreduce(&mut ep, step, &mut data).unwrap();
                    }
                })
            })
            .collect();
        handles.into_iter().for_each(|h| h.join().unwrap());
        println!(
            "  buffer pool over 16 ring rounds               recycled {} | allocated {}",
            pool.recycled(),
            pool.allocated()
        );
        counters.push(("ring16_pool_recycled".into(), pool.recycled() as f64));
        counters.push(("ring16_pool_allocated".into(), pool.allocated() as f64));
    }

    // ---- deadline/retry recv: clean-path cost -----------------------------
    // Every blocking receive now runs through `recv_deadline` (timeout
    // accounting + per-sender seq dedup + parked-queue lookup).  On the
    // clean path — in-order delivery, no faults — that machinery must be
    // allocation-free in steady state: the parked map is probed with
    // `get_mut` (no insertion), in-order seqs take the contiguous fast
    // path, and queued messages pop without blocking.  Self-sends are
    // forbidden by the fabric, so the probe drives a 2-endpoint fabric
    // from one thread: pre-queue from endpoint 0, drain on endpoint 1
    // with received payloads held live so pool recycling stays outside
    // the measured window.
    b.section("deadline/retry recv clean path (2 endpoints, pooled)");
    {
        let (mut eps, _) = Fabric::new(2);
        let mut e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        let buf = vec![1.0f32; 65_536];
        // warm: pool buffers, seq trackers, channel nodes
        for k in 0..4u64 {
            e0.send_copy(1, tags::grad(k, 0), &buf).unwrap();
        }
        for k in 0..4u64 {
            std::hint::black_box(e1.recv(0, tags::grad(k, 0)).unwrap());
        }
        const DRAIN: u64 = 32;
        for k in 0..DRAIN {
            e0.send_copy(1, tags::grad(4 + k, 0), &buf).unwrap();
        }
        let mut held = Vec::with_capacity(DRAIN as usize);
        let a0 = allocs();
        for k in 0..DRAIN {
            held.push(e1.recv(0, tags::grad(4 + k, 0)).unwrap());
        }
        let recv_allocs = allocs() - a0;
        drop(held);
        println!("  clean-path recv steady-state allocations      {recv_allocs} (want 0)");
        counters.push((
            "comm_clean_recv_steady_state_allocs".into(),
            recv_allocs as f64,
        ));
        assert_eq!(
            recv_allocs, 0,
            "deadline/dedup recv must not allocate on the in-order clean path"
        );

        // clean-path latency: send_copy + deadline-recv round, 64 KiB f32.
        // Recorded (not asserted): the honest number for what the
        // robustness plumbing costs when nothing goes wrong.
        let mut t = 1_000u64;
        stats.push(b.time_stat("p2p send_copy+recv 64KiB (deadline path)", 8, 64, || {
            e0.send_copy(1, tags::grad(t, 0), &buf).unwrap();
            std::hint::black_box(e1.recv(0, tags::grad(t, 0)).unwrap());
            t += 1;
        }));
    }

    // ---- arena vs seed: ring parameter hand-off ---------------------------
    b.section("ring param hand-off (4 hops, 1M f32)");
    let params_row: Vec<f32> = vec![0.5f32; 1_000_000];
    stats.push(b.time_stat("hand-off seed: clone per hop", 1, 10, || {
        run_handoff(&params_row, false);
    }));
    stats.push(b.time_stat("hand-off arena: payload handle", 1, 10, || {
        run_handoff(&params_row, true);
    }));

    // ---- eager bucketed reduction: overlap with backprop ------------------
    // Synthetic multi-worker step (artifact-free): each worker "computes"
    // a backward pass stage by stage (deterministic streaming passes over
    // the stage run) and either (a) eagerly launches each stage's bucket
    // ring the moment the stage lands, or (b) waits for the whole
    // backward before reducing — the step-boundary baseline.  The comm
    // timeline proves (a) starts reducing while backprop still runs.
    b.section("eager bucketed ring vs step-boundary ring (4 workers, synthetic bwd)");
    let mut ts_stats: Vec<harness::Stat> = Vec::new();
    let mut ts_counters: Vec<(String, f64)> = Vec::new();
    for (label, eager) in [
        ("step-boundary ring (reduce after bwd)", false),
        ("eager bucketed ring (overlapped)", true),
    ] {
        let st = b.time_stat(label, 1, 5, || {
            std::hint::black_box(run_synthetic_step(&layout, 4, 4, eager, false));
        });
        ts_stats.push(st.clone());
        stats.push(st);
    }
    // timeline proof: first grad-bucket send precedes the last backward
    // (a single step, so the overlap cannot come from step interleaving)
    let (tl_stats, _, _) = run_synthetic_step(&layout, 4, 1, true, true);
    let digest = instrument::overlap_from_stats(&tl_stats)
        .expect("grad sends and bwd marks recorded");
    let (first_send, last_bwd) = (digest.first_grad_send_ns, digest.last_bwd_done_ns);
    assert!(
        digest.overlapped(),
        "eager reduction must start before the last backward completes \
         (first send {first_send} ns vs last bwd {last_bwd} ns)"
    );
    println!(
        "  overlap: first grad send at {first_send} ns, last bwd done at {last_bwd} ns"
    );
    ts_counters.push(("overlap_first_grad_send_ns".into(), first_send as f64));
    ts_counters.push(("overlap_last_bwd_done_ns".into(), last_bwd as f64));
    ts_counters.push(("eager_starts_before_last_bwd".into(), 1.0));
    // pooled buffers: steady-state eager steps recycle, they don't allocate
    let (_, pool_alloc, pool_rec) = run_synthetic_step(&layout, 4, 12, true, false);
    println!(
        "  eager ring pool over 12 steps                 recycled {pool_rec} | allocated {pool_alloc}"
    );
    assert!(
        pool_rec > pool_alloc,
        "steady-state eager steps must be served by the pool \
         (recycled {pool_rec} vs allocated {pool_alloc})"
    );
    ts_counters.push(("eager_pool_recycled".into(), pool_rec as f64));
    ts_counters.push(("eager_pool_allocated".into(), pool_alloc as f64));

    // ---- structured tracing: disabled hook cost + enabled-ring steady state
    // The trace layer's cost contract (DESIGN-OBS.md): with tracing
    // disabled every hook is one relaxed atomic load — allocation-free
    // and low-single-digit nanoseconds; with tracing enabled, steady-state
    // records write into the preallocated ring (wrap overwrites the
    // oldest slot and counts a drop) without touching the heap.
    b.section("trace recorder: disabled hook vs enabled ring");
    {
        use cyclic_dp::trace::{self, Fields, TraceKind};
        assert!(!trace::enabled(), "recorder must start disabled");
        const OPS: u64 = 1_000_000;
        for i in 0..1_000u64 {
            trace::instant(TraceKind::Heartbeat, Fields { step: i, ..Fields::default() });
        }
        let a0 = allocs();
        let t0 = std::time::Instant::now();
        for i in 0..OPS {
            trace::instant(TraceKind::Heartbeat, Fields { step: i, ..Fields::default() });
        }
        let ns_per_op = t0.elapsed().as_nanos() as f64 / OPS as f64;
        let disabled_allocs = allocs() - a0;
        println!(
            "  disabled hook                                 {ns_per_op:.2} ns/op | {disabled_allocs} allocs (want 0)"
        );
        counters.push(("trace_disabled_overhead".into(), ns_per_op));
        counters.push(("trace_disabled_allocs".into(), disabled_allocs as f64));
        assert_eq!(
            disabled_allocs, 0,
            "disabled trace hook must not allocate"
        );

        // enabled ring: warm past the first wrap, then prove a steady
        // window of records never allocates while drops are counted
        const CAP: usize = 1024;
        trace::enable(CAP);
        for i in 0..(2 * CAP as u64) {
            trace::instant(TraceKind::Heartbeat, Fields { step: i, ..Fields::default() });
        }
        let a0 = allocs();
        for i in 0..(4 * CAP as u64) {
            trace::instant(TraceKind::Heartbeat, Fields { step: i, ..Fields::default() });
        }
        let enabled_allocs = allocs() - a0;
        let (events, dropped) = trace::drain();
        println!(
            "  enabled ring (cap {CAP})                       {enabled_allocs} allocs (want 0) | kept {} | dropped {dropped}",
            events.len()
        );
        counters.push(("trace_enabled_steady_state_allocs".into(), enabled_allocs as f64));
        assert_eq!(
            enabled_allocs, 0,
            "enabled ring record must not allocate in steady state"
        );
        assert_eq!(events.len(), CAP, "full ring drains exactly its capacity");
        assert!(dropped > 0, "wrapping ring must count overwritten events");
        assert!(!trace::enabled(), "drain must leave the recorder disabled");
    }

    // ---- native-backend training step (always runs, no artifacts) --------
    native_sections(&b, &mut stats, &mut ts_stats, &mut ts_counters);

    // ---- XLA bundle sections (feature `xla` + `make artifacts`) -----------
    #[cfg(feature = "xla")]
    xla_sections(&b, &mut stats, &mut ts_stats, &mut ts_counters);

    harness::write_json("BENCH_hotpath.json", "hotpath", &stats, &counters);
    harness::write_json("BENCH_trainstep.json", "trainstep", &ts_stats, &ts_counters);
}

/// Training-step measurements on the pure-Rust backend: these populate
/// the BENCH_trainstep trajectory in the artifact-free (native) CI lane.
fn native_sections(
    b: &harness::Bench,
    stats: &mut Vec<harness::Stat>,
    ts_stats: &mut Vec<harness::Stat>,
    ts_counters: &mut Vec<(String, f64)>,
) {
    b.section("native backend training step (synthetic mlp, no artifacts)");
    let rt = NativeBackend::default_mlp();
    let mut t = RefTrainer::new(&rt, Rule::CdpV2).unwrap();
    t.step().unwrap(); // warm
    let st = b.time_stat("native RefTrainer::step (cdp_v2)", 1, 10, || {
        t.step().unwrap();
    });
    ts_stats.push(st.clone());
    stats.push(st);
    // the native step allocates activation scratch per kernel call (its
    // hot-path contract covers parameter/gradient state, not activations)
    // — count it honestly rather than asserting zero
    let a0 = allocs();
    t.step().unwrap();
    let per_step = allocs() - a0;
    println!("  native step heap allocations                  {per_step}");
    ts_counters.push(("native_step_allocs".into(), per_step as f64));
    drop(t);

    let shared = SharedBackend(Arc::new(rt));
    let st = b.time_stat("native multi ring 2 steps (cdp_v2)", 1, 3, || {
        std::hint::black_box(
            multi::train(shared.clone(), Rule::CdpV2, multi::CommPattern::Ring, 2)
                .unwrap(),
        );
    });
    ts_stats.push(st.clone());
    stats.push(st);
    let st = b.time_stat("native multi barrier 2 steps (dp)", 1, 3, || {
        std::hint::black_box(
            multi::train(shared.clone(), Rule::Dp, multi::CommPattern::Barrier, 2)
                .unwrap(),
        );
    });
    ts_stats.push(st.clone());
    stats.push(st);

    let layout = ArenaLayout::from_manifest(shared.manifest());
    let mut flat_p = shared.init_params_flat().unwrap();
    let mut flat_m = layout.zeros();
    let mut flat_o = layout.zeros();
    let flat_g = layout.zeros();
    let st = b.time_stat("native sgd_update_flat all stages", 2, 20, || {
        for j in 0..shared.manifest().n_stages {
            let r = layout.stage_range(j);
            shared
                .sgd_update_flat(
                    j,
                    &flat_p[r.clone()],
                    &mut flat_m[r.clone()],
                    &flat_g[r.clone()],
                    0.01,
                    &mut flat_o[r],
                )
                .unwrap();
        }
        std::mem::swap(&mut flat_p, &mut flat_o);
    });
    ts_stats.push(st.clone());
    stats.push(st);
    ts_counters.push(("native_total_param_elems".into(), layout.total_len as f64));

    // ---- native vs scalar baseline ---------------------------------------
    // The tentpole contract (DESIGN-PERF.md §Kernel architecture): the
    // blocked/vectorized/pooled kernels against the retained scalar
    // reference, same trainer, same bundle, bit-identical losses — only
    // wall time may differ.  A larger shape than the default mlp so the
    // matmuls dominate per-call overhead.
    b.section("native vs scalar baseline (hidden 512, mb 32, cdp_v2)");
    let big = NativeBackend::synthetic(NativeMlpConfig {
        hidden: 512,
        microbatch: 32,
        ..NativeMlpConfig::default()
    });
    let mut tb = RefTrainer::new(&big, Rule::CdpV2).unwrap();
    set_kernel_mode(KernelMode::ScalarReference);
    tb.step().unwrap(); // warm the scalar path
    let st_scalar = b.time_stat("trainstep scalar reference (h512 mb32)", 0, 3, || {
        tb.step().unwrap();
    });
    set_kernel_mode(KernelMode::Fast);
    tb.step().unwrap(); // warm the fast path (pool already spawned)
    let st_fast = b.time_stat("trainstep fast kernels (h512 mb32)", 0, 3, || {
        tb.step().unwrap();
    });
    let speedup = st_scalar.mean_ns / st_fast.mean_ns.max(1.0);
    println!("  native vs scalar speedup                      {speedup:.2}×");
    ts_stats.push(st_scalar.clone());
    ts_stats.push(st_fast.clone());
    stats.push(st_scalar);
    stats.push(st_fast);
    ts_counters.push(("native_vs_scalar_speedup".into(), speedup));
    // The ≥4× floor is asserted only under CDP_BENCH_STRICT=1 (a shared
    // CI runner's scheduler noise should fail the committed-baseline
    // regression gate, not this smoke run).
    if std::env::var("CDP_BENCH_STRICT").as_deref() == Ok("1") {
        assert!(
            speedup >= 4.0,
            "fast kernels must be ≥4× the scalar reference (got {speedup:.2}×)"
        );
    }
}

/// The pre-split bundle measurements: literal conversion, executable
/// dispatch, literal-vs-device trainstep contrast, multi-worker overlap
/// and the per-tensor/arena optimizer comparison.  Needs the `xla`
/// feature and `make artifacts`; self-skips without the bundle.
#[cfg(feature = "xla")]
fn xla_sections(
    b: &harness::Bench,
    stats: &mut Vec<harness::Stat>,
    ts_stats: &mut Vec<harness::Stat>,
    ts_counters: &mut Vec<(String, f64)>,
) {
    use cyclic_dp::coordinator::{ExecMode, SharedRuntime};
    use cyclic_dp::data::DataSource;
    use cyclic_dp::model::artifacts_root;
    use cyclic_dp::runtime::{tensor_to_literal, BundleRuntime};

    if !harness::have_bundle("mlp") {
        return;
    }
    let rt = BundleRuntime::load(&artifacts_root().join("mlp")).unwrap();

    b.section("literal conversion (mlp stage-1 params)");
    let params = rt.init_params().unwrap();
    stats.push(b.time_stat("tensor_to_literal stage 1 (4 tensors)", 3, 100, || {
        for t in &params[1] {
            std::hint::black_box(tensor_to_literal(t).unwrap());
        }
    }));
    let flat = rt.init_params_flat().unwrap();
    let mlp_layout = ArenaLayout::from_manifest(&rt.manifest);
    stats.push(b.time_stat("param_literals_flat stage 1", 3, 100, || {
        std::hint::black_box(
            rt.param_literals_flat(1, &flat[mlp_layout.stage_range(1)]).unwrap(),
        );
    }));

    b.section("executable dispatch (mlp bundle)");
    let data = DataSource::from_manifest(&rt.manifest);
    let mb = data.microbatch(0, 0);
    let x = match &mb {
        cyclic_dp::data::MicroBatch::Class { x, .. } => x.clone(),
        _ => unreachable!(),
    };
    let hx = cyclic_dp::tensor::HostTensor::F32(x);
    stats.push(b.time_stat("stage_fwd(1)", 3, 50, || {
        let y = rt.stage_fwd(0, &params[0], &hx).unwrap();
        std::hint::black_box(y);
    }));

    b.section("end-to-end training step");
    let mut t = RefTrainer::new(&rt, Rule::CdpV2).unwrap();
    stats.push(b.time_stat("RefTrainer::step (cdp_v2, mlp)", 2, 10, || {
        t.step().unwrap();
    }));

    // ---- trainstep: literal vs device-resident ----------------------------
    // Per-step wall time and host↔device traffic for the two execution
    // paths, plus the device-residency contract: ≤ 1 stage-level
    // parameter upload per committed θ-version (the literal path pays
    // one per used version per step, forever).
    b.section("trainstep: literal vs device-resident (cdp_v2, mlp)");
    let n_stages = rt.manifest.n_stages;
    const TS_STEPS: usize = 5;

    let mut lit = RefTrainer::new(&rt, Rule::CdpV2).unwrap();
    lit.step().unwrap(); // warm
    rt.transfers.reset();
    let st = b.time_stat("step literal (host path)", 0, TS_STEPS, || {
        lit.step().unwrap();
    });
    let lit_h2d = rt.transfers.h2d_bytes() as f64 / TS_STEPS as f64;
    let lit_d2h = rt.transfers.d2h_bytes() as f64 / TS_STEPS as f64;
    let lit_uploads = rt.transfers.param_uploads() as f64 / TS_STEPS as f64;
    // native vs XLA: same oracle trainer and schedule, mlp-family model
    // on both — the ratio of this bundle's literal-path step to the
    // native synthetic step recorded by `native_sections` above
    if let Some(nat) = ts_stats
        .iter()
        .find(|s| s.label.starts_with("native RefTrainer::step"))
    {
        ts_counters.push((
            "xla_literal_vs_native_step_ratio".into(),
            st.mean_ns / nat.mean_ns.max(1.0),
        ));
    }
    ts_stats.push(st.clone());
    stats.push(st);

    let mut dev =
        RefTrainer::new_with_mode(&rt, Rule::CdpV2, ExecMode::DeviceResident).unwrap();
    dev.step().unwrap(); // warm (pays the θ-version-0 uploads)
    rt.transfers.reset();
    let up0 = dev.device_param_uploads().unwrap();
    let a0 = allocs();
    let st = b.time_stat("step device-resident", 0, TS_STEPS, || {
        dev.step().unwrap();
    });
    let dev_allocs = (allocs() - a0) as f64 / TS_STEPS as f64;
    let dev_h2d = rt.transfers.h2d_bytes() as f64 / TS_STEPS as f64;
    let dev_d2h = rt.transfers.d2h_bytes() as f64 / TS_STEPS as f64;
    let dev_uploads = (dev.device_param_uploads().unwrap() - up0) as f64 / TS_STEPS as f64;
    ts_stats.push(st.clone());
    stats.push(st);

    println!(
        "  literal: {lit_uploads:.1} param uploads/step, h2d {lit_h2d:.0} B/step, d2h {lit_d2h:.0} B/step"
    );
    println!(
        "  device:  {dev_uploads:.1} param uploads/step, h2d {dev_h2d:.0} B/step, d2h {dev_d2h:.0} B/step, {dev_allocs:.0} allocs/step"
    );
    // contract: one committed θ-version per step ⇒ ≤ n_stages uploads/step
    assert!(
        dev_uploads <= n_stages as f64 + 1e-9,
        "device path exceeded 1 upload per stage per θ-version: {dev_uploads}/step over {n_stages} stages"
    );
    // Since the host path's LitStore adopted the same ≤1-per-(stage,
    // θ-version) prep discipline (backend split), upload *counts* match;
    // the device path's remaining edge is avoiding the per-call literal
    // construction + conversion, visible in the wall-time rows above.
    assert!(
        dev_uploads <= lit_uploads + 1e-9,
        "device path must not upload more often than the literal path \
         ({dev_uploads} vs {lit_uploads} per step)"
    );
    ts_counters.push(("trainstep_steps".into(), TS_STEPS as f64));
    ts_counters.push(("literal_param_uploads_per_step".into(), lit_uploads));
    ts_counters.push(("literal_h2d_bytes_per_step".into(), lit_h2d));
    ts_counters.push(("literal_d2h_bytes_per_step".into(), lit_d2h));
    ts_counters.push(("device_param_uploads_per_step".into(), dev_uploads));
    ts_counters.push(("device_h2d_bytes_per_step".into(), dev_h2d));
    ts_counters.push(("device_d2h_bytes_per_step".into(), dev_d2h));
    ts_counters.push(("device_allocs_per_step".into(), dev_allocs));
    ts_counters.push(("n_stages".into(), n_stages as f64));
    // drop the trainers (and the device store's resident buffers) before
    // `rt` moves into the shared Arc below — device buffers must never
    // outlive the PJRT client that created them
    drop(t);
    drop(lit);
    drop(dev);

    b.section("multi-worker step (4 threads)");
    let shared = SharedRuntime(Arc::new(rt));

    // real-trainer overlap: the eager ring starts reducing before the
    // cluster's last backward stage completes (comm-stats timeline)
    {
        // a single step, so overlap cannot come from step interleaving
        let rep = multi::train_with(
            shared.clone(),
            Rule::CdpV2,
            multi::CommPattern::Ring,
            1,
            multi::MultiOpts {
                mode: ExecMode::DeviceResident,
                bucket_elems: 64,
                record_timeline: true,
                ..Default::default()
            },
        )
        .unwrap();
        let digest = instrument::overlap_from_events(&rep.timeline)
            .expect("grad sends and bwd marks");
        let (first_send, last_bwd) =
            (digest.first_grad_send_ns, digest.last_bwd_done_ns);
        assert!(
            digest.overlapped(),
            "trainer reduction must start before the last backward completes"
        );
        println!(
            "  multi ring overlap: first grad send {first_send} ns < last bwd {last_bwd} ns"
        );
        ts_counters.push(("multi_overlap_first_send_ns".into(), first_send as f64));
        ts_counters.push(("multi_overlap_last_bwd_ns".into(), last_bwd as f64));
    }
    stats.push(b.time_stat("multi ring 2 steps (cdp_v2)", 1, 3, || {
        std::hint::black_box(
            multi::train(shared.clone(), Rule::CdpV2, multi::CommPattern::Ring, 2)
                .unwrap(),
        );
    }));
    stats.push(b.time_stat("multi barrier 2 steps (dp)", 1, 3, || {
        std::hint::black_box(
            multi::train(shared.clone(), Rule::Dp, multi::CommPattern::Barrier, 2)
                .unwrap(),
        );
    }));

    let mut sgd_params = shared.init_params().unwrap();
    let mut moms = shared.zero_like_params();
    let grads = shared.zero_like_params();
    b.section("optimizer");
    stats.push(b.time_stat("sgd_update all stages (per-tensor)", 2, 20, || {
        for j in 0..shared.manifest.n_stages {
            shared
                .sgd_update(j, &mut sgd_params[j], &mut moms[j], &grads[j], 0.01)
                .unwrap();
        }
    }));
    let mut flat_p = shared.init_params_flat().unwrap();
    let mut flat_m = mlp_layout.zeros();
    let mut flat_o = mlp_layout.zeros();
    let flat_g = mlp_layout.zeros();
    stats.push(b.time_stat("sgd_update_flat all stages (arena)", 2, 20, || {
        for j in 0..shared.manifest.n_stages {
            let r = mlp_layout.stage_range(j);
            shared
                .sgd_update_flat(
                    j,
                    &flat_p[r.clone()],
                    &mut flat_m[r.clone()],
                    &flat_g[r.clone()],
                    0.01,
                    &mut flat_o[r],
                )
                .unwrap();
        }
        std::mem::swap(&mut flat_p, &mut flat_o);
    }));
}

/// Deterministic streaming passes standing in for one stage's backward
/// compute in the synthetic step.
fn synthetic_bwd(run: &mut [f32]) {
    for _ in 0..6 {
        scale(run, 1.000_001);
    }
}

/// Synthetic multi-worker training step over the bench's 8-stage layout:
/// per stage (backward order), every worker streams passes over its
/// stage run ("backward compute"), then reduces that stage over the ring
/// — eagerly (bucketed hop per stage, interleaved with the remaining
/// backward) or at the step boundary (all compute, then all reduction).
/// Returns (fabric stats, pool allocated, pool recycled).
fn run_synthetic_step(
    layout: &Arc<ArenaLayout>,
    n: usize,
    steps: u64,
    eager: bool,
    timeline: bool,
) -> (Arc<CommStats>, u64, u64) {
    let (eps, stats) = Fabric::new(n);
    if timeline {
        stats.enable_timeline();
    }
    let pool = eps[0].pool().clone();
    let reducer = BucketedReducer::new(8 * 1024);
    let handles: Vec<_> = eps
        .into_iter()
        .map(|mut ep| {
            let layout = layout.clone();
            std::thread::spawn(move || {
                let owner = ep.n - 1;
                let w = ep.id;
                let ring = RingView::full(&ep);
                let mut gmb: Vec<f32> = (0..layout.total_len)
                    .map(|k| ((w + k) as f32 * 1e-3).sin())
                    .collect();
                let mut avg = layout.zeros();
                for t in 0..steps {
                    if eager {
                        for j in (0..layout.n_stages()).rev() {
                            let r = layout.stage_range(j);
                            synthetic_bwd(&mut gmb[r.clone()]);
                            ep.stats().mark(EventKind::BwdStageDone, w, j, t, 0);
                            let out = if w == owner {
                                Some(&mut avg[r.clone()])
                            } else {
                                None
                            };
                            reducer
                                .ring_stage(&mut ep, &ring, &layout, t, j, &gmb[r], out)
                                .unwrap();
                        }
                    } else {
                        for j in (0..layout.n_stages()).rev() {
                            let r = layout.stage_range(j);
                            synthetic_bwd(&mut gmb[r]);
                            ep.stats().mark(EventKind::BwdStageDone, w, j, t, 0);
                        }
                        for j in (0..layout.n_stages()).rev() {
                            let r = layout.stage_range(j);
                            let out = if w == owner {
                                Some(&mut avg[r.clone()])
                            } else {
                                None
                            };
                            reducer
                                .ring_stage(&mut ep, &ring, &layout, t, j, &gmb[r], out)
                                .unwrap();
                        }
                    }
                }
                std::hint::black_box(avg.first().copied());
            })
        })
        .collect();
    handles.into_iter().for_each(|h| h.join().unwrap());
    (stats, pool.allocated(), pool.recycled())
}

/// The seed fabric's ring all-reduce: identical schedule, but every send
/// clones the chunk into a fresh `Vec` (what `Endpoint::send` did before
/// payloads were pooled).  Kept here as the A/B baseline.
fn ring_allreduce_unpooled(ep: &mut Endpoint, step: u64, data: &mut [f32]) {
    let n = ep.n;
    if n == 1 {
        return;
    }
    let len = data.len();
    let chunk = |c: usize| -> std::ops::Range<usize> {
        let base = len / n;
        let rem = len % n;
        let start = c * base + c.min(rem);
        let size = base + usize::from(c < rem);
        start..start + size
    };
    let me = ep.id;
    for p in 0..n - 1 {
        let send_c = (me + n - p) % n;
        let recv_c = (me + n - p - 1) % n;
        ep.send(ep.right(), tags::ring(step, p), data[chunk(send_c)].to_vec())
            .unwrap();
        let part = ep.recv(ep.left(), tags::ring(step, p)).unwrap();
        add_into(&mut data[chunk(recv_c)], &part);
    }
    for p in 0..n - 1 {
        let send_c = (me + 1 + n - p) % n;
        let recv_c = (me + n - p) % n;
        ep.send(
            ep.right(),
            tags::ring(step, n + p),
            data[chunk(send_c)].to_vec(),
        )
        .unwrap();
        let part = ep.recv(ep.left(), tags::ring(step, n + p)).unwrap();
        data[chunk(recv_c)].copy_from_slice(&part);
    }
}

/// Parameter hand-off around a 4-ring: rank 0 produces the fresh
/// parameters, every other rank forwards them on.  `zero_copy` forwards
/// the received payload handle; otherwise each hop clones into a fresh
/// `Vec` (the seed behavior).
fn run_handoff(params: &[f32], zero_copy: bool) {
    let (eps, _) = Fabric::new(4);
    let src = params.to_vec();
    let handles: Vec<_> = eps
        .into_iter()
        .map(|mut ep| {
            let src = src.clone();
            std::thread::spawn(move || {
                let n = ep.n;
                if ep.id == 0 {
                    ep.send_copy(1, tags::param(0, 0), &src).unwrap();
                } else {
                    let got = ep.recv(ep.left(), tags::param(0, 0)).unwrap();
                    if ep.id + 1 < n {
                        if zero_copy {
                            ep.send(ep.id + 1, tags::param(0, 0), got.clone()).unwrap();
                        } else {
                            ep.send(ep.id + 1, tags::param(0, 0), got.to_vec()).unwrap();
                        }
                    }
                    std::hint::black_box(got[0]);
                }
            })
        })
        .collect();
    handles.into_iter().for_each(|h| h.join().unwrap());
}
