//! Tolerance contract of the `CDPTRACE1` JSONL parser (ISSUE 10
//! satellite): corrupt input is *counted*, never fatal, and well-formed
//! events survive byte-exactly — including a property round-trip over
//! the full event-kind vocabulary.

use cyclic_dp::testing;
use cyclic_dp::trace::{
    parse_jsonl, parse_jsonl_file, parse_jsonl_reader, to_jsonl, write_jsonl, Fields, TraceEvent,
    TraceKind, TRACE_MAGIC,
};

fn ev(kind: TraceKind, ns: u64, step: u64) -> TraceEvent {
    TraceEvent::new(kind, ns, 0, Fields { step, ..Fields::default() })
}

#[test]
fn empty_and_blank_inputs_parse_to_nothing() {
    for text in ["", "\n", "\n\n\r\n  \n"] {
        let p = parse_jsonl(text);
        assert_eq!(p.version, None);
        assert_eq!(p.dropped, 0);
        assert!(p.events.is_empty());
        assert_eq!(p.skipped, 0, "blank lines are not corruption: {text:?}");
    }
}

#[test]
fn truncated_final_line_is_skipped_not_fatal() {
    let mut text = to_jsonl(&[ev(TraceKind::StepBegin, 10, 0), ev(TraceKind::StepEnd, 20, 0)], 0);
    // simulate a crash mid-flush: chop the last line in half
    let cut = text.len() - 12;
    text.truncate(cut);
    let p = parse_jsonl(&text);
    assert_eq!(p.version.as_deref(), Some(TRACE_MAGIC));
    assert_eq!(p.events.len(), 1, "the intact line survives");
    assert_eq!(p.skipped, 1, "the truncated line is counted");
}

#[test]
fn interleaved_garbage_and_unknown_kinds_are_counted() {
    let good = ev(TraceKind::Fwd, 5, 1);
    let text = format!(
        "{{\"v\":\"{TRACE_MAGIC}\",\"dropped\":2}}\n\
         not json at all\n\
         {}\n\
         {{\"k\":\"warp_drive\",\"ns\":9}}\n\
         {{\"no_kind\":1}}\n\
         [1,2,3]\n",
        good.to_json_line()
    );
    let p = parse_jsonl(&text);
    assert_eq!(p.version.as_deref(), Some(TRACE_MAGIC));
    assert_eq!(p.dropped, 2);
    assert_eq!(p.events, vec![good]);
    // garbage line + unknown future kind + kind-less object + non-object
    assert_eq!(p.skipped, 4);
}

#[test]
fn crlf_line_endings_parse_cleanly() {
    let unix = to_jsonl(&[ev(TraceKind::Loss, 1, 0), ev(TraceKind::Sgd, 2, 0)], 1);
    let dos = unix.replace('\n', "\r\n");
    let p = parse_jsonl(&dos);
    assert_eq!(p.version.as_deref(), Some(TRACE_MAGIC));
    assert_eq!(p.dropped, 1);
    assert_eq!(p.events.len(), 2);
    assert_eq!(p.skipped, 0, "CRLF is not corruption");
}

#[test]
fn headerless_stream_still_yields_events() {
    // a tail of a rotated file: events with no header line
    let text = format!("{}\n{}\n", ev(TraceKind::Bwd, 1, 0).to_json_line(),
        ev(TraceKind::GradSend, 2, 0).to_json_line());
    let p = parse_jsonl(&text);
    assert_eq!(p.version, None);
    assert_eq!(p.dropped, 0);
    assert_eq!(p.events.len(), 2);
}

#[test]
fn only_first_header_wins() {
    // concatenated files: the second header must not clobber the first
    let a = to_jsonl(&[ev(TraceKind::StepBegin, 1, 0)], 3);
    let b = to_jsonl(&[ev(TraceKind::StepEnd, 2, 0)], 9);
    let p = parse_jsonl(&format!("{a}{b}"));
    assert_eq!(p.version.as_deref(), Some(TRACE_MAGIC));
    assert_eq!(p.dropped, 3, "first header's drop count is kept");
    assert_eq!(p.events.len(), 2, "events from both segments survive");
}

#[test]
fn reader_and_file_paths_agree_with_str_parse() {
    let events = vec![
        ev(TraceKind::Fwd, 1, 0),
        TraceEvent::loss(2, 1, -0.125),
        TraceEvent::new(
            TraceKind::Kernel,
            7,
            13,
            Fields { stage: 3, step: 2, bits: 1, ..Fields::default() },
        ),
    ];
    let text = to_jsonl(&events, 4);
    let from_str = parse_jsonl(&text);
    let from_reader = parse_jsonl_reader(std::io::Cursor::new(text.clone())).unwrap();
    assert_eq!(from_str.events, from_reader.events);
    assert_eq!(from_str.dropped, from_reader.dropped);

    let dir = std::env::temp_dir().join(format!("cdp-trace-parser-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("t.jsonl");
    write_jsonl(&path, &events, 4).unwrap();
    let from_file = parse_jsonl_file(&path).unwrap();
    assert_eq!(from_file.events, events);
    assert_eq!(from_file.dropped, 4);
    assert_eq!(from_file.version.as_deref(), Some(TRACE_MAGIC));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn missing_trace_file_is_an_error_not_a_panic() {
    let err = parse_jsonl_file(std::path::Path::new("/nonexistent/cdp-no-such-trace.jsonl"));
    assert!(err.is_err(), "I/O failures propagate; only content is tolerant");
}

#[test]
fn property_round_trip_over_every_kind() {
    // Timestamps/counters stay below 2^53 (the format's f64-exact range);
    // `bits` exercises all 64 bits — it rides as a hex string.
    const MAX_EXACT: u64 = 1 << 53;
    testing::check("trace-jsonl-round-trip", 200, |g| {
        let n = g.usize_in(0, 12);
        let events: Vec<TraceEvent> = (0..n)
            .map(|_| {
                TraceEvent::new(
                    *g.choose(&TraceKind::ALL),
                    g.u64() % MAX_EXACT,
                    g.u64() % MAX_EXACT,
                    Fields {
                        worker: g.usize_in(0, 64) as u32,
                        stage: g.usize_in(0, 64) as u32,
                        step: g.u64() % MAX_EXACT,
                        version: g.u64() % MAX_EXACT,
                        bytes: g.u64() % MAX_EXACT,
                        bits: g.u64(),
                    },
                )
            })
            .collect();
        let dropped = g.u64() % MAX_EXACT;
        let p = parse_jsonl(&to_jsonl(&events, dropped));
        assert_eq!(p.version.as_deref(), Some(TRACE_MAGIC));
        assert_eq!(p.dropped, dropped);
        assert_eq!(p.skipped, 0);
        assert_eq!(p.events, events);
    });
}
