//! Integration tests over the full stack: artifacts (L1 Pallas kernels in
//! L2 staged HLO) executed by the L3 coordinators.
//!
//! Require the `xla` feature (the PJRT path) plus `make artifacts`
//! (tiny + mlp bundles).  Each test skips with a message if artifacts are
//! missing so `cargo test` stays green pre-build; the whole file is
//! compiled out of the default (native) build — rust/tests/native_backend.rs
//! covers the same trainer-equivalence matrix there.

#![cfg(feature = "xla")]

use std::sync::{Arc, OnceLock};

use cyclic_dp::coordinator::{multi, pipeline, single, zero, SharedRuntime};
use cyclic_dp::model::artifacts_root;
use cyclic_dp::parallel::Rule;
use cyclic_dp::runtime::BundleRuntime;

fn runtime(bundle: &str) -> Option<SharedRuntime> {
    static TINY: OnceLock<Option<SharedRuntime>> = OnceLock::new();
    static MLP: OnceLock<Option<SharedRuntime>> = OnceLock::new();
    let cell = match bundle {
        "tiny" => &TINY,
        "mlp" => &MLP,
        _ => panic!("unknown test bundle"),
    };
    let name = bundle.to_string();
    cell.get_or_init(move || {
        let dir = artifacts_root().join(&name);
        if !dir.join("manifest.json").exists() {
            eprintln!("SKIP: bundle {name} missing — run `make artifacts`");
            return None;
        }
        Some(SharedRuntime(Arc::new(
            BundleRuntime::load(&dir).expect("load bundle"),
        )))
    })
    .clone()
}

const RULES: [Rule; 3] = [Rule::Dp, Rule::CdpV1, Rule::CdpV2];

// ---------------------------------------------------------------- golden --
#[test]
fn golden_losses_match_python_mirror() {
    for bundle in ["tiny", "mlp"] {
        let Some(rt) = runtime(bundle) else { return };
        let golden = rt
            .manifest
            .load_golden()
            .unwrap()
            .expect("bundle ships golden.json");
        let steps = rt.manifest.golden_steps;
        for (rule_name, expect) in golden {
            let rule = cyclic_dp::parallel::rule_by_name(&rule_name).unwrap();
            let mut t = single::RefTrainer::new(&rt, rule).unwrap();
            let logs = t.train(steps).unwrap();
            for (log, want) in logs.iter().zip(&expect) {
                let rel = (log.loss - want).abs() / want.abs().max(1e-9);
                assert!(
                    rel < 5e-3,
                    "{bundle}/{rule_name} step {}: rust {} python {} rel {rel:.2e}",
                    log.step,
                    log.loss,
                    want
                );
            }
        }
    }
}

// ----------------------------------------------------- rule-level checks --
#[test]
fn rules_agree_at_step0_and_diverge_after() {
    let Some(rt) = runtime("mlp") else { return };
    let mut first = Vec::new();
    let mut third = Vec::new();
    for rule in RULES {
        let mut t = single::RefTrainer::new(&rt, rule).unwrap();
        let logs = t.train(3).unwrap();
        first.push(logs[0].loss);
        third.push(logs[2].loss);
    }
    // θ_{−1} := θ_0 bootstrap ⇒ identical first step
    assert_eq!(first[0], first[1]);
    assert_eq!(first[0], first[2]);
    // the delay is real ⇒ different step-2 losses
    assert_ne!(third[0], third[1]);
    assert_ne!(third[1], third[2]);
}

#[test]
fn randomized_rule_trains() {
    let Some(rt) = runtime("mlp") else { return };
    let rule = Rule::Randomized { p_fresh: 0.5, seed: 0xDE1A7 };
    let mut t = single::RefTrainer::new(&rt, rule).unwrap();
    let logs = t.train(10).unwrap();
    assert!(logs[9].loss < logs[0].loss, "randomized-delay rule must learn");
}

// --------------------------------------------- trainer equivalence matrix --
#[test]
fn multi_barrier_matches_reference_dp() {
    let Some(rt) = runtime("mlp") else { return };
    let mut reference = single::RefTrainer::new(&rt, Rule::Dp).unwrap();
    let want: Vec<f64> = reference.train(4).unwrap().iter().map(|l| l.loss).collect();
    let rep = multi::train(rt.clone(), Rule::Dp, multi::CommPattern::Barrier, 4).unwrap();
    let got: Vec<f64> = rep.logs.iter().map(|l| l.loss).collect();
    assert_eq!(got, want, "threaded DP must be bit-identical to reference");
    assert!(rep.comm_bytes > 0);
    assert_eq!(rep.optimizer_replicas, rt.manifest.n_microbatches);
}

#[test]
fn multi_ring_matches_reference_for_cdp_rules() {
    let Some(rt) = runtime("mlp") else { return };
    for rule in [Rule::CdpV1, Rule::CdpV2] {
        let mut reference = single::RefTrainer::new(&rt, rule.clone()).unwrap();
        let want: Vec<f64> =
            reference.train(4).unwrap().iter().map(|l| l.loss).collect();
        let rep =
            multi::train(rt.clone(), rule.clone(), multi::CommPattern::Ring, 4).unwrap();
        let got: Vec<f64> = rep.logs.iter().map(|l| l.loss).collect();
        assert_eq!(got, want, "ring CDP ({}) must match reference", rule.name());
        assert_eq!(rep.optimizer_replicas, 1, "ring keeps one optimizer copy");
    }
}

#[test]
fn zero_both_flows_match_reference() {
    let Some(rt) = runtime("mlp") else { return };
    for (rule, flow) in [
        (Rule::Dp, zero::StateFlow::Broadcast),
        (Rule::CdpV2, zero::StateFlow::Cyclic),
        (Rule::CdpV1, zero::StateFlow::Cyclic),
    ] {
        let mut reference = single::RefTrainer::new(&rt, rule.clone()).unwrap();
        let want: Vec<f64> =
            reference.train(3).unwrap().iter().map(|l| l.loss).collect();
        let rep = zero::train(rt.clone(), rule.clone(), flow, 3).unwrap();
        let got: Vec<f64> = rep.logs.iter().map(|l| l.loss).collect();
        assert_eq!(got, want, "zero ({}) must match reference", rule.name());
    }
}

#[test]
fn zero_cyclic_halves_boundary_concurrency() {
    let Some(rt) = runtime("mlp") else { return };
    let b = zero::train(rt.clone(), Rule::Dp, zero::StateFlow::Broadcast, 2).unwrap();
    let c = zero::train(rt.clone(), Rule::CdpV2, zero::StateFlow::Cyclic, 2).unwrap();
    let n = rt.manifest.n_microbatches as u64;
    assert_eq!(b.max_msgs_per_timestep, n - 1);
    assert_eq!(c.max_msgs_per_timestep, 1);
    // volume is the same order (paper: unchanged)
    let ratio = b.comm_bytes as f64 / c.comm_bytes as f64;
    assert!(ratio > 0.5 && ratio < 2.0, "volume ratio {ratio}");
}

#[test]
fn pipeline_1f1b_matches_reference_and_2bw_is_v1() {
    let Some(rt) = runtime("mlp") else { return };
    for rule in RULES {
        let mut reference = single::RefTrainer::new(&rt, rule.clone()).unwrap();
        let want: Vec<f64> =
            reference.train(3).unwrap().iter().map(|l| l.loss).collect();
        let rep =
            pipeline::train(&rt, rule.clone(), pipeline::PipeSchedule::OneFOneB, 3)
                .unwrap();
        let got: Vec<f64> = rep.logs.iter().map(|l| l.loss).collect();
        assert_eq!(got, want, "pipeline ({}) must match reference", rule.name());
    }
}

#[test]
fn pipeline_gpipe_bubble_exceeds_1f1b_stash_bound() {
    let Some(rt) = runtime("mlp") else { return };
    let g = pipeline::train(&rt, Rule::Dp, pipeline::PipeSchedule::GPipe, 1).unwrap();
    let o = pipeline::train(&rt, Rule::CdpV1, pipeline::PipeSchedule::OneFOneB, 1)
        .unwrap();
    assert!(g.bubble_fraction > 0.0);
    // 1F1B bounds the stash: never worse than GPipe's peak
    assert!(o.peak_stash_bytes <= g.peak_stash_bytes);
    assert_eq!(g.param_versions, 1);
    assert_eq!(o.param_versions, 2);
}

// ------------------------------------------------------------- learning ---
#[test]
fn cdp_v2_learns_classification_to_accuracy() {
    let Some(rt) = runtime("mlp") else { return };
    let mut t = single::RefTrainer::new(&rt, Rule::CdpV2).unwrap();
    let logs = t.train(30).unwrap();
    assert!(logs[29].loss < logs[0].loss * 0.8, "loss should drop");
    let acc = t.accuracy(8).unwrap();
    assert!(acc > 0.5, "10-class accuracy {acc} (random = 0.1)");
}

#[test]
fn transformer_lm_learns_below_unigram_floor() {
    let Some(rt) = runtime("tiny") else { return };
    let mut t = single::RefTrainer::new(&rt, Rule::CdpV2).unwrap();
    let logs = t.train(40).unwrap();
    // vocab 64 ⇒ uniform = ln 64 ≈ 4.16; Markov structure is learnable
    // down to ~ln 16 ≈ 2.77.  40 tiny steps must show a clear downward
    // trend (the full-scale run in examples/train_lm.rs goes further).
    let first = logs[0].loss;
    let last = logs[39].loss;
    assert!(
        last < first - 0.25,
        "LM should be learning: step0 {first} → step39 {last}"
    );
    let eval = t.eval_loss(4).unwrap();
    assert!(eval < 4.3, "eval loss {eval}");
}

// --------------------------------------------------------- runtime edges --
#[test]
fn manifest_artifacts_all_compile_and_shapes_roundtrip() {
    let Some(rt) = runtime("tiny") else { return };
    let params = rt.init_params().unwrap();
    assert_eq!(params.len(), rt.manifest.n_stages);
    for (st, spec) in params.iter().zip(&rt.manifest.stages) {
        assert_eq!(st.len(), spec.params.len());
        for (t, p) in st.iter().zip(&spec.params) {
            assert_eq!(t.shape, p.shape);
            assert!(t.is_finite());
        }
    }
}

#[test]
fn missing_bundle_is_a_clean_error() {
    let err = BundleRuntime::load(&artifacts_root().join("no_such_bundle"));
    assert!(err.is_err());
}
