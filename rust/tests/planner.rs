//! Auto-planner integration tests (`cargo test -q planner`):
//!
//! - the search's schedule ordering agrees with `sim::analytic`'s Table 1
//!   on regimes where the table is unambiguous (latency-dominated comm,
//!   k ≥ 4: O(1) cyclic rows must outrank log-N DP rows);
//! - a searched [`Plan`] round-trips bit-exactly through its file format;
//! - an over-budget search fails with the typed error naming the cheapest
//!   infeasible candidate;
//! - the winning plan executes end-to-end through
//!   [`cyclic_dp::coordinator::execute_plan`] on a repartitioned native
//!   backend, and a mismatched backend is refused.

use std::sync::Arc;

use cyclic_dp::coordinator::{execute_plan, SharedBackend};
use cyclic_dp::plan::{search, Plan, PlanError, SearchSpace, TrainerKind, Variant};
use cyclic_dp::profile::{ModelProfile, ProfileOpts, StageProfile, StageProfiler};
use cyclic_dp::runtime::{NativeBackend, NativeMlpConfig, Precision};
use cyclic_dp::sim::analytic::table1_rows;

/// Hand-built profile with explicit compute/comm weights (mirrors the
/// unit-test helper in `plan::search`, at lps = 1 so every stage count
/// dividing `k0` is in the default space).
fn synth_profile(
    k0: usize,
    layer_ns: f64,
    sgd_ns: f64,
    bnd: u64,
    psi: u64,
    bw: f64,
    lat: f64,
) -> ModelProfile {
    let stages: Vec<StageProfile> = (0..k0)
        .map(|j| StageProfile {
            stage: j,
            fwd_ns: 0.4 * layer_ns,
            bwd_ns: 0.6 * layer_ns,
            sgd_ns: sgd_ns / k0 as f64,
            boundary_bytes: if j + 1 < k0 { bnd } else { 0 },
            param_bytes: psi / k0 as u64,
            grad_buckets: 1,
            grad_bucket_bytes: psi / k0 as u64,
            act_bytes: bnd,
        })
        .collect();
    ModelProfile {
        model: "planner-test".into(),
        stages,
        microbatch: 8,
        n_microbatches: k0,
        psi_p_bytes: psi,
        peak_act_bytes: bnd * k0 as u64,
        layer_costs_ns: vec![layer_ns; k0],
        bw_bytes_per_ns: bw,
        hop_latency_ns: lat,
        bf16_step_ratio: 1.0,
        single_step_ns: 0.0,
        multi_step_ns: 0.0,
        host_threads: 8,
        calib_steps: 2,
        alloc_per_step: 0,
    }
}

/// Candidate lookup at a fixed (trainer, variant, rule) cell of the
/// ranked table, pinned to stage count `k`, the smallest bucket, f32.
fn find<'a>(
    ranked: &'a cyclic_dp::plan::RankedPlans,
    space: &SearchSpace,
    t: TrainerKind,
    v: Variant,
    rule: &str,
    k: u32,
) -> &'a cyclic_dp::plan::Candidate {
    ranked
        .candidates
        .iter()
        .find(|c| {
            c.plan.trainer == t
                && c.plan.variant == v
                && c.plan.rule.name() == rule
                && c.plan.n_stages == k
                && c.plan.bucket_elems == space.bucket_elems[0]
                && c.plan.precision == Precision::F32
        })
        .unwrap_or_else(|| panic!("no candidate {t:?}/{v:?}/{rule} at k{k}"))
}

#[test]
fn planner_ranking_agrees_with_table1_where_unambiguous() {
    // Latency-dominated fabric: per-hop latency dwarfs both byte time
    // (high bandwidth) and compute.  In this regime Table 1's comm-step
    // column decides the ordering, and for k ≥ 4 it is unambiguous:
    // cyclic rows are O(1), DP rows are log₂N ≥ 2.
    for k in [4usize, 8] {
        let rows = table1_rows(k);
        let steps = |name: &str| {
            rows.iter().find(|r| r.implementation == name).unwrap().max_comm_steps
        };
        // Precondition: the analytic table itself must be unambiguous.
        assert!(steps("Multi-GPU DP") > steps("Multi-GPU + Cyclic"));
        assert!(steps("ZeRO-DP") > steps("ZeRO-DP + Cyclic"));

        let p = synth_profile(k, 500.0, 200.0, 1 << 10, 4 << 20, 100.0, 50_000.0);
        let space = SearchSpace::for_profile(&p);
        let ranked = search(&p, u64::MAX, &space).unwrap();
        let kk = k as u32;

        let ring = find(&ranked, &space, TrainerKind::Multi, Variant::Ring, "cdp_v2", kk);
        let barrier =
            find(&ranked, &space, TrainerKind::Multi, Variant::Barrier, "dp", kk);
        assert!(
            ring.plan.predicted_step_ns < barrier.plan.predicted_step_ns,
            "k={k}: table1 says cyclic ring ({}) beats barrier dp ({})",
            ring.plan.predicted_step_ns,
            barrier.plan.predicted_step_ns
        );
        assert!(ring.comm_ns < barrier.comm_ns, "the win must come from comm");

        let zc = find(&ranked, &space, TrainerKind::Zero, Variant::Cyclic, "cdp_v2", kk);
        let zb = find(&ranked, &space, TrainerKind::Zero, Variant::Broadcast, "dp", kk);
        assert!(
            zc.plan.predicted_step_ns < zb.plan.predicted_step_ns,
            "k={k}: ZeRO cyclic flow must outrank broadcast"
        );
        assert!(zc.comm_ns < zb.comm_ns);
    }
}

#[test]
fn planner_plans_round_trip_through_files() {
    let p = synth_profile(4, 800.0, 300.0, 1 << 12, 2 << 20, 10.0, 500.0);
    let ranked = search(&p, u64::MAX, &SearchSpace::for_profile(&p)).unwrap();
    let dir = std::env::temp_dir();
    // The winner and the worst-ranked candidate both survive the file.
    for (tag, cand) in [
        ("winner", ranked.winner()),
        ("last", ranked.candidates.last().unwrap()),
    ] {
        let path = dir.join(format!(
            "cdp-planner-test-{tag}-{}.plan",
            std::process::id()
        ));
        cand.plan.save(&path).unwrap();
        let loaded = Plan::load(&path).unwrap();
        assert_eq!(loaded, cand.plan, "{tag} plan must round-trip bit-exactly");
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn planner_over_budget_is_typed_and_names_the_cheapest() {
    let p = synth_profile(4, 800.0, 300.0, 1 << 12, 2 << 20, 10.0, 500.0);
    let space = SearchSpace::for_profile(&p);
    let err = search(&p, 1, &space).unwrap_err();
    let PlanError::NoFeasiblePlan { budget_bytes, cheapest, cheapest_bytes } = err else {
        panic!("expected NoFeasiblePlan, got {err:?}");
    };
    assert_eq!(budget_bytes, 1);
    assert!(cheapest_bytes > 1);
    // Cross-check against the unbounded ranking: the named candidate is
    // the true memory minimum of the same space.
    let ranked = search(&p, u64::MAX, &space).unwrap();
    let min_peak = ranked
        .candidates
        .iter()
        .map(|c| c.plan.predicted_peak_bytes)
        .min()
        .unwrap();
    assert_eq!(cheapest_bytes, min_peak);
    assert!(
        ranked
            .candidates
            .iter()
            .any(|c| c.plan.label() == cheapest
                && c.plan.predicted_peak_bytes == min_peak),
        "error must name an actual minimum-memory candidate, got `{cheapest}`"
    );
    // The error also renders its numbers.
    let msg = PlanError::NoFeasiblePlan {
        budget_bytes,
        cheapest: cheapest.clone(),
        cheapest_bytes,
    }
    .to_string();
    assert!(msg.contains(&cheapest) && msg.contains(&cheapest_bytes.to_string()));
}

#[test]
fn planner_winner_executes_end_to_end_on_native() {
    let cfg = NativeMlpConfig { layers_per_stage: 2, ..NativeMlpConfig::tiny() };
    let profiler = StageProfiler::new(ProfileOpts {
        calib_steps: 2,
        probe_fabric: false,
        calibrate_trainers: false,
    });
    let profile = profiler.profile_native(&cfg).unwrap();
    let ranked = search(&profile, u64::MAX, &SearchSpace::for_profile(&profile)).unwrap();
    let plan = &ranked.winner().plan;

    let rt = NativeBackend::synthetic(cfg)
        .repartitioned(plan.n_stages as usize)
        .unwrap()
        .with_precision(plan.precision);
    let logs = execute_plan(SharedBackend(Arc::new(rt)), plan, 2).unwrap();
    assert_eq!(logs.len(), 2, "two steps logged for `{}`", plan.label());
    for l in &logs {
        assert!(l.loss.is_finite(), "step {} loss must be finite", l.step);
    }

    // A backend on the wrong partition is refused, not silently retrained.
    if let Some(other_k) = [1usize, 2, 4]
        .into_iter()
        .find(|&k| k != plan.n_stages as usize)
    {
        let wrong = NativeBackend::synthetic(cfg).repartitioned(other_k).unwrap();
        let err = execute_plan(SharedBackend(Arc::new(wrong)), plan, 1).unwrap_err();
        assert!(
            err.to_string().contains("repartition"),
            "mismatch error must say how to fix it: {err}"
        );
    }
}
