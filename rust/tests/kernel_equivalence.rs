//! Kernel-equivalence property suite (DESIGN-PERF.md §Kernel
//! architecture, "Test enforcement"): the blocked/vectorized/pooled fast
//! kernels are **bit-identical** to the retained scalar reference in f32,
//! invariant to the pool's thread count, and the bf16 precision knob is
//! deterministic and toleranced against f32.
//!
//! The tests call `ops::scalar::*` directly for the reference arm and the
//! dispatching entry points for the candidate arm, so they hold whatever
//! the process-global dispatch mode happens to be — the two modes agree
//! bit-for-bit by contract, which is exactly what is being checked.

use std::sync::Arc;

use cyclic_dp::coordinator::{multi, single, SharedBackend};
use cyclic_dp::parallel::Rule;
use cyclic_dp::runtime::{Backend, NativeBackend, Precision};
use cyclic_dp::tensor::ops::{self, scalar};
use cyclic_dp::testing::{check, Gen};
use cyclic_dp::util::par::{self, with_threads};

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: element {i} differs ({x} vs {y})"
        );
    }
}

/// Random matrix with a sprinkling of exact zeros (exercises the scalar
/// matmul's zero-skip) and magnitudes spanning several binades.
fn mat(g: &mut Gen, len: usize) -> Vec<f32> {
    (0..len)
        .map(|_| {
            if g.usize_in(0, 5) == 0 {
                0.0
            } else {
                g.f32_in(-2.0, 2.0)
            }
        })
        .collect()
}

// ------------------------------------------------ fast == scalar, bitwise --
#[test]
fn fast_kernels_bit_match_scalar_reference_on_random_shapes() {
    par::warm();
    check("fast==scalar kernels", 40, |g| {
        let m = g.usize_in(1, 33);
        let k = g.usize_in(1, 65);
        let n = g.usize_in(1, 49);
        let a = mat(g, m * k);
        let b = mat(g, k * n);
        let gy = mat(g, m * n);

        // matmul: dst [m,n] = a [m,k] · b [k,n] (overwrites — seeding dst
        // with random garbage checks both modes clear it)
        let mut fast = mat(g, m * n);
        let mut slow = fast.clone();
        ops::matmul(&mut fast, &a, &b, m, k, n);
        scalar::matmul(&mut slow, &a, &b, m, k, n);
        assert_bits_eq(&fast, &slow, "matmul");

        // matmul_tn: dst [k,n] = aᵀ [k,m] · gy [m,n]
        let mut fast_tn = vec![0.0; k * n];
        let mut slow_tn = vec![0.0; k * n];
        ops::matmul_tn(&mut fast_tn, &a, &gy, m, k, n);
        scalar::matmul_tn(&mut slow_tn, &a, &gy, m, k, n);
        assert_bits_eq(&fast_tn, &slow_tn, "matmul_tn");

        // matmul_nt_acc: dst [m,k] += gy [m,n] · bᵀ (b as [k,n])
        let mut fast_nt = mat(g, m * k);
        let mut slow_nt = fast_nt.clone();
        ops::matmul_nt_acc(&mut fast_nt, &gy, &b, m, n, k);
        scalar::matmul_nt_acc(&mut slow_nt, &gy, &b, m, n, k);
        assert_bits_eq(&fast_nt, &slow_nt, "matmul_nt_acc");

        // fused bias_add_relu over [m,n] rows
        let bias = mat(g, n);
        let mut fast_br = gy.clone();
        let mut slow_br = gy.clone();
        ops::bias_add_relu(&mut fast_br, &bias);
        scalar::bias_add_relu(&mut slow_br, &bias);
        assert_bits_eq(&fast_br, &slow_br, "bias_add_relu");
    });
}

// ------------------------------------------- thread-count invariance ------
#[test]
fn kernel_results_do_not_depend_on_thread_count() {
    par::warm();
    check("thread-count invariance", 20, |g| {
        let m = g.usize_in(1, 40);
        let k = g.usize_in(1, 70);
        let n = g.usize_in(1, 40);
        let a = mat(g, m * k);
        let b = mat(g, k * n);
        let gy = mat(g, m * n);

        let run_all = |threads: usize| {
            with_threads(threads, || {
                let mut c = vec![0.0; m * n];
                ops::matmul(&mut c, &a, &b, m, k, n);
                let mut tn = vec![0.0; k * n];
                ops::matmul_tn(&mut tn, &a, &gy, m, k, n);
                let mut nt = vec![0.0; m * k];
                ops::matmul_nt_acc(&mut nt, &gy, &b, m, n, k);
                (c, tn, nt)
            })
        };
        let serial = run_all(1);
        for threads in [2usize, 3, 8] {
            let par_r = run_all(threads);
            assert_bits_eq(&serial.0, &par_r.0, "matmul across thread counts");
            assert_bits_eq(&serial.1, &par_r.1, "matmul_tn across thread counts");
            assert_bits_eq(&serial.2, &par_r.2, "matmul_nt_acc across thread counts");
        }
    });
}

/// The whole oracle trainer, serial vs pooled: the loss sequence is the
/// observable the four-trainer equivalence suite compares, so it must be
/// bit-identical at any `RAYON_NUM_THREADS`.
#[test]
fn reference_trainer_losses_are_thread_count_invariant() {
    par::warm();
    let losses_at = |threads: usize| -> Vec<u64> {
        with_threads(threads, || {
            let rt = NativeBackend::default_mlp();
            let mut t = single::RefTrainer::new(&rt, Rule::CdpV2).unwrap();
            t.train(3)
                .unwrap()
                .iter()
                .map(|l| l.loss.to_bits())
                .collect()
        })
    };
    let serial = losses_at(1);
    for threads in [2usize, 4, 16] {
        assert_eq!(
            losses_at(threads),
            serial,
            "loss bits changed between 1 and {threads} partitioning threads"
        );
    }
}

// ------------------------------------------------- sgd partition parity ---
#[test]
fn sgd_update_flat_matches_serial_loop_bitwise() {
    par::warm();
    let rt = NativeBackend::default_mlp();
    let layout = rt.layout().clone();
    let mu = rt.manifest.momentum;
    let params = rt.init_params_flat().unwrap();
    let grads: Vec<f32> = (0..layout.total_len).map(|i| ((i as f32) * 0.37).sin()).collect();
    let lr = 0.01f32;

    for j in 0..rt.manifest.n_stages {
        let r = layout.stage_range(j);
        let (p, g) = (&params[r.clone()], &grads[r.clone()]);
        // hand-rolled serial reference
        let mut want_m: Vec<f32> = g.iter().map(|x| x * 0.5).collect();
        let mut want_o = vec![0.0f32; p.len()];
        for i in 0..p.len() {
            let m = mu * want_m[i] + g[i];
            want_o[i] = p[i] - lr * m;
            want_m[i] = m;
        }
        // backend kernel (pool-partitioned in fast mode)
        let mut got_m: Vec<f32> = g.iter().map(|x| x * 0.5).collect();
        let mut got_o = vec![0.0f32; p.len()];
        rt.sgd_update_flat(j, p, &mut got_m, g, lr, &mut got_o).unwrap();
        assert_bits_eq(&got_m, &want_m, "sgd momentum");
        assert_bits_eq(&got_o, &want_o, "sgd params");
        // and invariant to the partition target
        let mut m1: Vec<f32> = g.iter().map(|x| x * 0.5).collect();
        let mut o1 = vec![0.0f32; p.len()];
        with_threads(1, || rt.sgd_update_flat(j, p, &mut m1, g, lr, &mut o1).unwrap());
        assert_bits_eq(&m1, &want_m, "sgd momentum serial");
        assert_bits_eq(&o1, &want_o, "sgd params serial");
    }
}

// --------------------------------------------------------- bf16 contract --
/// bf16 runs are deterministic and bit-identical *across trainers* (the
/// rounding points are schedule-independent), and track the f32 oracle to
/// rounding tolerance.
#[test]
fn bf16_trainers_agree_bitwise_and_track_f32() {
    let host = |p: Precision| -> Vec<f64> {
        let rt = NativeBackend::default_mlp().with_precision(p);
        let mut t = single::RefTrainer::new(&rt, Rule::CdpV2).unwrap();
        t.train(3).unwrap().iter().map(|l| l.loss).collect()
    };
    let f32_losses = host(Precision::F32);
    let bf_single = host(Precision::Bf16);
    let bf_again = host(Precision::Bf16);
    assert_eq!(bf_single, bf_again, "bf16 oracle must be run-to-run deterministic");
    for (s, f) in bf_single.iter().zip(&f32_losses) {
        let rel = (s - f).abs() / f.abs().max(1e-9);
        assert!(rel < 0.05, "bf16 {s} vs f32 {f} (rel {rel:.2e})");
    }

    // cross-trainer bit-identity holds in bf16 exactly as in f32
    let shared = SharedBackend(Arc::new(
        NativeBackend::default_mlp().with_precision(Precision::Bf16),
    ));
    let rep =
        multi::train(shared.clone(), Rule::CdpV2, multi::CommPattern::Ring, 3).unwrap();
    let got: Vec<f64> = rep.logs.iter().map(|l| l.loss).collect();
    assert_eq!(
        got, bf_single,
        "bf16 ring trainer must be bit-identical to the bf16 oracle"
    );
}
