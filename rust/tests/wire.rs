//! Wire-transport integration suite (DESIGN-ROBUSTNESS.md, "Crossing a
//! real wire"): the framed UDS/TCP transport must be a drop-in for the
//! in-process channel fabric — same losses bit-for-bit, same typed
//! errors when a peer is unreachable — and scripted socket faults
//! (disconnects, truncated frames, stalled peers) must be absorbed by
//! the reconnect supervisor + seq-dedup without perturbing training.

use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use cyclic_dp::cluster::run_workers;
use cyclic_dp::comm::{
    tags, BufferPool, CommError, CommStats, Endpoint, Fabric, WireConfig, WireFaultPlan,
    WireKind, WireTransport,
};
use cyclic_dp::coordinator::{multi, zero, SharedBackend, StepLog};
use cyclic_dp::parallel::Rule;
use cyclic_dp::runtime::NativeBackend;

const STEPS: usize = 4;

fn rdv(label: &str) -> PathBuf {
    std::env::temp_dir().join(format!("cdp-wire-{label}-{}", std::process::id()))
}

fn native() -> NativeBackend {
    NativeBackend::default_mlp()
}

fn losses(logs: &[StepLog]) -> Vec<f64> {
    logs.iter().map(|l| l.loss).collect()
}

// --------------------------------------------------------- p2p round trip --

fn roundtrip(kind: WireKind, label: &str) {
    let dir = rdv(label);
    let cfg = WireConfig::new(kind, &dir, 2);
    let (mut eps, stats) = Fabric::wire(&cfg).unwrap();
    let mut e1 = eps.pop().unwrap();
    let mut e0 = eps.pop().unwrap();

    let body = vec![0.5f32, -1.25, f32::EPSILON, 3.75e-30];
    e0.send(1, tags::param(3, 0), body.clone()).unwrap();
    let p = e1.recv(0, tags::param(3, 0)).unwrap();
    assert_eq!(p.len(), body.len());
    for (a, b) in p.iter().zip(&body) {
        assert_eq!(a.to_bits(), b.to_bits(), "payload must cross the wire bit-exactly");
    }

    e1.send(0, tags::loss(7), vec![42.0]).unwrap();
    assert_eq!(&e0.recv(1, tags::loss(7)).unwrap()[..], &[42.0]);
    assert!(stats.messages() >= 2);

    drop(e0);
    drop(e1);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn uds_endpoints_round_trip_tagged_payloads() {
    roundtrip(WireKind::Uds, "p2p-uds");
}

#[test]
fn tcp_endpoints_round_trip_tagged_payloads() {
    roundtrip(WireKind::Tcp, "p2p-tcp");
}

// ------------------------------------------- trainer equivalence over wire --
// The whole fleet lives in one test process (each worker a thread), but
// every byte crosses a real socket: `Fabric::wire` binds one wire
// endpoint per worker in the shared rendezvous dir.

fn run_multi_over_wire(kind: WireKind, label: &str, faults: WireFaultPlan) -> Vec<f64> {
    let shared = SharedBackend(Arc::new(native()));
    let n = shared.manifest().n_microbatches;
    let dir = rdv(label);
    let mut cfg = WireConfig::new(kind, &dir, n);
    cfg.faults = faults;
    let (endpoints, _stats) = Fabric::wire(&cfg).unwrap();
    let eps: Arc<Vec<Mutex<Option<Endpoint>>>> =
        Arc::new(endpoints.into_iter().map(|e| Mutex::new(Some(e))).collect());

    let shared_c = shared.clone();
    let results = run_workers(n, move |w| {
        let mut ep = eps[w].lock().unwrap().take().unwrap();
        multi::run_worker(
            &shared_c,
            &Rule::CdpV2,
            multi::CommPattern::Ring,
            STEPS,
            multi::MultiOpts::default(),
            None,
            &mut ep,
        )
    });
    let mut logs = Vec::new();
    for (w, r) in results.into_iter().enumerate() {
        let (l, _ck) = r.unwrap_or_else(|e| panic!("wire worker {w} failed: {e:#}"));
        if w == 0 {
            logs = l;
        }
    }
    std::fs::remove_dir_all(&dir).ok();
    losses(&logs)
}

fn run_zero_over_wire(kind: WireKind, label: &str) -> Vec<f64> {
    let shared = SharedBackend(Arc::new(native()));
    let n = shared.manifest().n_microbatches;
    let dir = rdv(label);
    let cfg = WireConfig::new(kind, &dir, n);
    let (endpoints, _stats) = Fabric::wire(&cfg).unwrap();
    let eps: Arc<Vec<Mutex<Option<Endpoint>>>> =
        Arc::new(endpoints.into_iter().map(|e| Mutex::new(Some(e))).collect());

    let shared_c = shared.clone();
    let results = run_workers(n, move |w| {
        let mut ep = eps[w].lock().unwrap().take().unwrap();
        zero::run_worker(
            &shared_c,
            &Rule::CdpV2,
            zero::StateFlow::Cyclic,
            STEPS,
            zero::ZeroOpts::default(),
            None,
            &mut ep,
        )
    });
    let mut logs = Vec::new();
    for (w, r) in results.into_iter().enumerate() {
        let (l, _peak, _ck) = r.unwrap_or_else(|e| panic!("wire worker {w} failed: {e:#}"));
        if w == 0 {
            logs = l;
        }
    }
    std::fs::remove_dir_all(&dir).ok();
    losses(&logs)
}

#[test]
fn multi_ring_over_uds_matches_the_in_process_fabric() {
    let want = losses(
        &multi::train(
            SharedBackend(Arc::new(native())),
            Rule::CdpV2,
            multi::CommPattern::Ring,
            STEPS,
        )
        .unwrap()
        .logs,
    );
    let got = run_multi_over_wire(WireKind::Uds, "multi-uds", WireFaultPlan::default());
    assert_eq!(got, want, "uds fabric diverged from in-process channels");
}

#[test]
fn multi_ring_over_tcp_matches_the_in_process_fabric() {
    let want = losses(
        &multi::train(
            SharedBackend(Arc::new(native())),
            Rule::CdpV2,
            multi::CommPattern::Ring,
            STEPS,
        )
        .unwrap()
        .logs,
    );
    let got = run_multi_over_wire(WireKind::Tcp, "multi-tcp", WireFaultPlan::default());
    assert_eq!(got, want, "tcp fabric diverged from in-process channels");
}

#[test]
fn zero_cyclic_over_uds_matches_the_in_process_fabric() {
    let want = losses(
        &zero::train(
            SharedBackend(Arc::new(native())),
            Rule::CdpV2,
            zero::StateFlow::Cyclic,
            STEPS,
        )
        .unwrap()
        .logs,
    );
    let got = run_zero_over_wire(WireKind::Uds, "zero-uds");
    assert_eq!(got, want, "zero over uds diverged from in-process channels");
}

// ----------------------------------------------------- scripted wire faults --
// Mid-step disconnects drop the socket under live traffic: the
// supervisor reconnects with backoff and replays its redelivery window,
// seq-dedup discards what already arrived, and losses stay bit-identical.
// Truncated frames exercise the reader's discard-and-resync path; a
// stalled peer leans on the receive deadline's patience.

#[test]
fn scripted_disconnects_truncations_and_stalls_recover_bit_identically() {
    let want = losses(
        &multi::train(
            SharedBackend(Arc::new(native())),
            Rule::CdpV2,
            multi::CommPattern::Ring,
            STEPS,
        )
        .unwrap()
        .logs,
    );
    let faults = WireFaultPlan::default()
        .disconnect(1, 2, 3) // drop the 1→2 socket before its 4th frame
        .disconnect(0, 1, 5)
        .truncate(2, 3, 2) // ship half a frame on 2→3, then drop it
        .stall(3, 0, 1, 50); // 3→0 freezes 50ms mid-stream
    let got = run_multi_over_wire(WireKind::Uds, "multi-uds-faulted", faults);
    assert_eq!(got, want, "scripted wire faults must not perturb training");
}

// ------------------------------------------------------------ typed errors --

#[test]
fn unreachable_peer_becomes_peergone_and_timeout_with_decoded_tags() {
    let dir = rdv("gone");
    let mut cfg = WireConfig::new(WireKind::Uds, &dir, 3);
    cfg.connect_deadline = Duration::from_millis(300);
    // Bind worker 0 only — worker 2 never shows up at the rendezvous.
    let pool = BufferPool::new();
    let stats = Arc::new(CommStats::default());
    let t0 = WireTransport::bind(0, &cfg, pool.clone()).unwrap();
    let mut e0 = Endpoint::over(0, 3, Box::new(t0), stats, pool);

    // The first send queues; the supervisor burns its connect deadline
    // in the writer thread and then marks the edge gone.
    let _ = e0.send(2, tags::param(3, 2), vec![1.0]);
    std::thread::sleep(Duration::from_millis(700));
    match e0.send(2, tags::param(4, 2), vec![1.0]) {
        Err(CommError::PeerGone { peer, tag }) => {
            assert_eq!(peer, 2);
            assert_eq!(tag.ns_name(), "param");
            assert_eq!(tag.step, 4);
        }
        other => panic!("expected PeerGone, got {other:?}"),
    }

    // Receiving from the silent peer is a deadline timeout, tags intact.
    match e0.recv_deadline(2, tags::param(4, 2), Duration::from_millis(50)) {
        Err(CommError::Timeout { peer, tag, .. }) => {
            assert_eq!(peer, 2);
            assert_eq!(tag.ns_name(), "param");
            assert_eq!(tag.step, 4);
        }
        other => panic!("expected Timeout, got {other:?}"),
    }

    drop(e0);
    std::fs::remove_dir_all(&dir).ok();
}
