//! End-to-end trace verification (ISSUE 10 tentpole acceptance): run
//! each trainer with the recorder capturing, then machine-check the
//! paper's claims on the resulting event stream —
//!
//! * every cyclic-rule trainer satisfies the constant-activation-memory
//!   envelope and balanced per-interval gradient traffic;
//! * the barrier baseline *fails* the balance check (and `expect=spike`
//!   turns that demonstrated failure into the passing assertion).

use std::sync::Arc;

use cyclic_dp::coordinator::single::RefTrainer;
use cyclic_dp::coordinator::{multi, pipeline, zero, SharedBackend};
use cyclic_dp::parallel::Rule;
use cyclic_dp::runtime::NativeBackend;
use cyclic_dp::testing::instrument;
use cyclic_dp::trace::{capture, verify, Expect, TraceEvent, TraceKind, VerifyOpts};

const STEPS: usize = 3;
const CAP: usize = 1 << 16;

fn shared() -> SharedBackend<NativeBackend> {
    SharedBackend(Arc::new(NativeBackend::default_mlp()))
}

fn count(events: &[TraceEvent], kind: TraceKind) -> usize {
    events.iter().filter(|e| e.kind == kind).count()
}

#[test]
fn multi_ring_trace_verifies_balanced_and_constant_memory() {
    let (rep, events, dropped) = capture(CAP, || {
        multi::train(shared(), Rule::CdpV2, multi::CommPattern::Ring, STEPS).unwrap()
    });
    assert_eq!(rep.logs.len(), STEPS);
    assert_eq!(dropped, 0, "ring capacity must hold a short run");

    let r = verify(&events, &VerifyOpts::default());
    assert!(r.mem.evaluated, "activation events span ≥ 2 steps");
    assert!(r.mem.ok, "constant-memory envelope: {:?}", r.mem);
    assert!(r.balance.evaluated, "grad sends + bwd boundaries recorded");
    assert!(r.balance.balanced, "eager ring is balanced: {:?}", r.balance);
    assert!(r.ok);

    // lifecycle coverage: every worker logged its step begin/end pairs,
    // losses flowed through the stream, and the stash ledger is balanced
    assert_eq!(count(&events, TraceKind::StepBegin), count(&events, TraceKind::StepEnd));
    assert_eq!(count(&events, TraceKind::Loss), STEPS);
    assert_eq!(count(&events, TraceKind::ActAlloc), count(&events, TraceKind::ActFree));

    // the overlap digest the benches assert, derivable from the same trace
    let d = instrument::overlap_from_trace(&events).expect("sends and bwd spans");
    assert!(d.overlapped(), "eager reduction starts before the last backward");
}

#[test]
fn multi_barrier_trace_demonstrates_the_spike() {
    let (rep, events, _) = capture(CAP, || {
        multi::train(shared(), Rule::Dp, multi::CommPattern::Barrier, STEPS).unwrap()
    });
    assert_eq!(rep.logs.len(), STEPS);

    let balanced = verify(&events, &VerifyOpts::default());
    assert!(balanced.mem.ok, "the barrier still has constant memory: {:?}", balanced.mem);
    assert!(balanced.balance.evaluated);
    assert!(
        !balanced.balance.balanced,
        "whole-model send after backward must spike: {:?}",
        balanced.balance
    );
    assert!(!balanced.ok, "a barrier trace must fail the balanced expectation");

    let spike = verify(&events, &VerifyOpts { expect: Expect::Spike, ..VerifyOpts::default() });
    assert!(spike.ok, "expect=spike certifies the demonstrated failure");
}

#[test]
fn zero_cyclic_trace_verifies() {
    let (rep, events, dropped) = capture(CAP, || {
        zero::train(shared(), Rule::CdpV2, zero::StateFlow::Cyclic, STEPS).unwrap()
    });
    assert_eq!(rep.logs.len(), STEPS);
    assert_eq!(dropped, 0);

    let r = verify(&events, &VerifyOpts::default());
    assert!(r.mem.evaluated && r.mem.ok, "{:?}", r.mem);
    assert!(r.balance.evaluated, "eager shard sends recorded");
    assert!(r.balance.balanced, "{:?}", r.balance);
    assert!(r.ok);
    assert!(count(&events, TraceKind::ParamSend) > 0, "cyclic param hand-off traced");
}

#[test]
fn pipeline_trace_verifies_constant_memory() {
    for sched in [pipeline::PipeSchedule::GPipe, pipeline::PipeSchedule::OneFOneB] {
        let rt = NativeBackend::default_mlp();
        let (rep, events, dropped) =
            capture(CAP, || pipeline::train(&rt, Rule::CdpV2, sched, STEPS).unwrap());
        assert_eq!(rep.logs.len(), STEPS);
        assert_eq!(dropped, 0);

        // the pipeline reduces in-process (no gradient wire traffic), so
        // the balance check self-skips; memory is the claim under test —
        // its stash ledger must mirror into a constant per-step envelope
        let r = verify(&events, &VerifyOpts::default());
        assert!(r.mem.evaluated, "{sched:?}: ≥ 2 steps of stash events");
        assert!(r.mem.ok, "{sched:?}: {:?}", r.mem);
        assert!(r.ok, "{sched:?}");
        assert_eq!(count(&events, TraceKind::ActAlloc), count(&events, TraceKind::ActFree));
    }
}

#[test]
fn single_trainer_trace_verifies() {
    let rt = NativeBackend::default_mlp();
    let ((), events, dropped) = capture(CAP, || {
        let mut t = RefTrainer::new(&rt, Rule::CdpV2).unwrap();
        for _ in 0..STEPS {
            t.step().unwrap();
        }
    });
    assert_eq!(dropped, 0);
    let r = verify(&events, &VerifyOpts::default());
    assert!(r.mem.evaluated && r.mem.ok, "{:?}", r.mem);
    assert!(r.ok);
    assert_eq!(count(&events, TraceKind::Loss), STEPS);
    assert_eq!(count(&events, TraceKind::StepBegin), STEPS);
    assert_eq!(count(&events, TraceKind::StepEnd), STEPS);
}
