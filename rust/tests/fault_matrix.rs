//! CI fault-matrix smoke: one seeded fault configuration per trainer,
//! sized to finish in seconds.  The exhaustive equivalences live in
//! `tests/robustness.rs`; this suite is the fast signal the fault lane
//! runs on every push (`cargo test --release --test fault_matrix`).

use std::sync::Arc;

use cyclic_dp::comm::FaultPlan;
use cyclic_dp::coordinator::{multi, pipeline, single, zero, SharedBackend};
use cyclic_dp::parallel::{Checkpoint, Rule};
use cyclic_dp::runtime::NativeBackend;

fn losses(logs: &[cyclic_dp::coordinator::StepLog]) -> Vec<f64> {
    logs.iter().map(|l| l.loss).collect()
}

#[test]
fn smoke_multi_ring_lossy() {
    let shared = SharedBackend(Arc::new(NativeBackend::default_mlp()));
    let want = losses(
        &multi::train(shared.clone(), Rule::CdpV2, multi::CommPattern::Ring, 10)
            .unwrap()
            .logs,
    );
    let rep = multi::train_with(
        shared,
        Rule::CdpV2,
        multi::CommPattern::Ring,
        10,
        multi::MultiOpts {
            faults: Some(FaultPlan::lossy(0x530_0AE, 0.05)),
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(losses(&rep.logs), want);
}

#[test]
fn smoke_zero_cyclic_lossy() {
    let shared = SharedBackend(Arc::new(NativeBackend::default_mlp()));
    let want = losses(
        &zero::train(shared.clone(), Rule::CdpV2, zero::StateFlow::Cyclic, 10)
            .unwrap()
            .logs,
    );
    let rep = zero::train_with(
        shared,
        Rule::CdpV2,
        zero::StateFlow::Cyclic,
        10,
        zero::ZeroOpts {
            faults: Some(FaultPlan::lossy(0x530_0AF, 0.05)),
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(losses(&rep.logs), want);
}

#[test]
fn smoke_single_checkpoint_wire_resume() {
    let rt = NativeBackend::default_mlp();
    let mut clean = single::RefTrainer::new(&rt, Rule::CdpV1).unwrap();
    let want = losses(&clean.train(4).unwrap());
    let mut head = single::RefTrainer::new(&rt, Rule::CdpV1).unwrap();
    let mut got = losses(&head.train(2).unwrap());
    let ck = Checkpoint::from_bytes(&head.checkpoint().to_bytes()).unwrap();
    let mut tail = single::RefTrainer::resume(&rt, Rule::CdpV1, ck).unwrap();
    got.extend(losses(&tail.train(2).unwrap()));
    assert_eq!(got, want);
}

#[test]
fn smoke_pipeline_checkpoint_resume() {
    let rt = NativeBackend::default_mlp();
    let sched = pipeline::PipeSchedule::OneFOneB;
    let want = losses(&pipeline::train(&rt, Rule::CdpV2, sched, 4).unwrap().logs);
    let head = pipeline::train_with(
        &rt,
        Rule::CdpV2,
        sched,
        2,
        pipeline::PipeOpts { checkpoint_at: Some(1), ..Default::default() },
    )
    .unwrap();
    let ck = head.checkpoint.unwrap();
    let tail =
        pipeline::resume_with(&rt, Rule::CdpV2, sched, 2, Default::default(), ck)
            .unwrap();
    let mut got = losses(&head.logs);
    got.extend(losses(&tail.logs));
    assert_eq!(got, want);
}
