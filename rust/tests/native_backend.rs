//! Cross-backend / native-backend test suite: the full trainer
//! equivalence matrix on the pure-Rust [`NativeBackend`] — zero
//! artifacts, zero network, the suite the required CI lane runs — plus
//! gradient checks of the hand-written backward, the arena-view
//! placement property, and (when the `xla` feature and artifacts are
//! both present) native-vs-XLA loss agreement.

use std::sync::Arc;

use cyclic_dp::coordinator::{multi, pipeline, single, zero, SharedBackend};
use cyclic_dp::parallel::arena::ArenaLayout;
use cyclic_dp::parallel::Rule;
use cyclic_dp::runtime::{Backend, NativeBackend, NativeMlpConfig};
use cyclic_dp::tensor::HostTensor;

const RULES: [Rule; 3] = [Rule::Dp, Rule::CdpV1, Rule::CdpV2];

fn native() -> NativeBackend {
    NativeBackend::default_mlp()
}

fn host_losses(rt: &NativeBackend, rule: Rule, steps: usize) -> Vec<f64> {
    let mut t = single::RefTrainer::new(rt, rule).unwrap();
    t.train(steps).unwrap().iter().map(|l| l.loss).collect()
}

// --------------------------------------------- trainer equivalence matrix --
#[test]
fn multi_barrier_matches_reference_dp() {
    let rt = native();
    let want = host_losses(&rt, Rule::Dp, 4);
    let shared = SharedBackend(Arc::new(rt));
    let rep =
        multi::train(shared.clone(), Rule::Dp, multi::CommPattern::Barrier, 4).unwrap();
    let got: Vec<f64> = rep.logs.iter().map(|l| l.loss).collect();
    assert_eq!(got, want, "threaded DP must be bit-identical to reference");
    assert!(rep.comm_bytes > 0);
    assert_eq!(rep.optimizer_replicas, shared.manifest().n_microbatches);
}

#[test]
fn multi_ring_matches_reference_for_cdp_rules() {
    let rt = native();
    let shared = SharedBackend(Arc::new(rt));
    for rule in [Rule::CdpV1, Rule::CdpV2] {
        let want = host_losses(&shared, rule.clone(), 4);
        let rep =
            multi::train(shared.clone(), rule.clone(), multi::CommPattern::Ring, 4)
                .unwrap();
        let got: Vec<f64> = rep.logs.iter().map(|l| l.loss).collect();
        assert_eq!(got, want, "ring CDP ({}) must match reference", rule.name());
        assert_eq!(rep.optimizer_replicas, 1, "ring keeps one optimizer copy");
    }
}

#[test]
fn zero_both_flows_match_reference() {
    let shared = SharedBackend(Arc::new(native()));
    for (rule, flow) in [
        (Rule::Dp, zero::StateFlow::Broadcast),
        (Rule::CdpV2, zero::StateFlow::Cyclic),
        (Rule::CdpV1, zero::StateFlow::Cyclic),
    ] {
        let want = host_losses(&shared, rule.clone(), 3);
        let rep = zero::train(shared.clone(), rule.clone(), flow, 3).unwrap();
        let got: Vec<f64> = rep.logs.iter().map(|l| l.loss).collect();
        assert_eq!(got, want, "zero ({}) must match reference", rule.name());
    }
}

#[test]
fn zero_cyclic_halves_boundary_concurrency() {
    let shared = SharedBackend(Arc::new(native()));
    let b = zero::train(shared.clone(), Rule::Dp, zero::StateFlow::Broadcast, 2).unwrap();
    let c = zero::train(shared.clone(), Rule::CdpV2, zero::StateFlow::Cyclic, 2).unwrap();
    let n = shared.manifest().n_microbatches as u64;
    assert_eq!(b.max_msgs_per_timestep, n - 1);
    assert_eq!(c.max_msgs_per_timestep, 1);
    let ratio = b.comm_bytes as f64 / c.comm_bytes as f64;
    assert!(ratio > 0.5 && ratio < 2.0, "volume ratio {ratio}");
}

#[test]
fn pipeline_both_schedules_match_reference() {
    let rt = native();
    for rule in RULES {
        let want = host_losses(&rt, rule.clone(), 3);
        for sched in [pipeline::PipeSchedule::OneFOneB, pipeline::PipeSchedule::GPipe] {
            let rep = pipeline::train(&rt, rule.clone(), sched, 3).unwrap();
            let got: Vec<f64> = rep.logs.iter().map(|l| l.loss).collect();
            assert_eq!(
                got,
                want,
                "pipeline {sched:?} ({}) must match reference",
                rule.name()
            );
        }
    }
}

#[test]
fn bucket_size_does_not_change_losses() {
    let shared = SharedBackend(Arc::new(native()));
    let want = host_losses(&shared, Rule::CdpV2, 3);
    for bucket_elems in [1usize, 3, 7, 1 << 20] {
        let rep = multi::train_with(
            shared.clone(),
            Rule::CdpV2,
            multi::CommPattern::Ring,
            3,
            multi::MultiOpts {
                bucket_elems,
                record_timeline: false,
                ..Default::default()
            },
        )
        .unwrap();
        let got: Vec<f64> = rep.logs.iter().map(|l| l.loss).collect();
        assert_eq!(got, want, "bucket_elems={bucket_elems} changed the losses");
    }
}

// ----------------------------------------------------- rule-level checks --
#[test]
fn rules_agree_at_step0_and_diverge_after() {
    let rt = native();
    let mut first = Vec::new();
    let mut third = Vec::new();
    for rule in RULES {
        let logs = host_losses(&rt, rule, 3);
        first.push(logs[0]);
        third.push(logs[2]);
    }
    // θ_{−1} := θ_0 bootstrap ⇒ identical first step
    assert_eq!(first[0], first[1]);
    assert_eq!(first[0], first[2]);
    // the delay is real ⇒ different step-2 losses
    assert_ne!(third[0], third[1]);
    assert_ne!(third[1], third[2]);
}

#[test]
fn cdp_v2_learns_classification() {
    let rt = native();
    let mut t = single::RefTrainer::new(&rt, Rule::CdpV2).unwrap();
    let logs = t.train(30).unwrap();
    assert!(
        logs[29].loss < logs[0].loss * 0.8,
        "loss should drop: {} → {}",
        logs[0].loss,
        logs[29].loss
    );
    let acc = t.accuracy(8).unwrap();
    assert!(acc > 0.5, "10-class accuracy {acc} (random = 0.1)");
}

#[test]
fn determinism_across_runs() {
    let a = host_losses(&native(), Rule::CdpV2, 3);
    let b = host_losses(&native(), Rule::CdpV2, 3);
    assert_eq!(a, b, "same bundle + rule ⇒ bit-identical runs");
}

// --------------------------------------------------- backward correctness --
/// Central-difference gradient check of the hand-written native backward
/// on a tiny 2-stage model: assemble the analytic model-wide gradient
/// from last_bwd + first_bwd, then perturb every single parameter and
/// compare against (L(θ+ε) − L(θ−ε)) / 2ε.
#[test]
fn native_backward_matches_finite_differences() {
    let rt = NativeBackend::synthetic(NativeMlpConfig::tiny());
    let layout = ArenaLayout::from_manifest(rt.manifest());
    let flat = rt.init_params_flat().unwrap();
    let data = cyclic_dp::data::DataSource::from_manifest(rt.manifest());
    let cyclic_dp::data::MicroBatch::Class { x, labels } = data.microbatch(0, 0) else {
        panic!("classification bundle")
    };

    let loss_of = |params: &[f32]| -> f32 {
        let a = rt
            .stage_fwd_flat(0, &params[layout.stage_range(0)], &HostTensor::F32(x.clone()))
            .unwrap();
        rt.last_fwd_loss_flat(&params[layout.stage_range(1)], &a, &labels).unwrap()
    };

    // analytic gradient via the backward chain
    let mut exec = rt.executor(cyclic_dp::coordinator::ExecMode::HostLiteral);
    let mut g = layout.zeros();
    let a1 = rt
        .stage_fwd_flat(0, &flat[layout.stage_range(0)], &HostTensor::F32(x.clone()))
        .unwrap();
    let (loss, gx) = rt
        .last_bwd(
            &mut exec,
            0,
            &flat[layout.stage_range(1)],
            &HostTensor::F32(a1),
            &labels,
            &mut g[layout.stage_range(1)],
        )
        .unwrap();
    assert!(loss.is_finite());
    rt.first_bwd(
        &mut exec,
        0,
        &flat[layout.stage_range(0)],
        &HostTensor::F32(x.clone()),
        &gx,
        &mut g[layout.stage_range(0)],
    )
    .unwrap();

    let eps = 1e-2f32;
    let mut worst = 0f32;
    let mut theta = flat.clone();
    for i in 0..theta.len() {
        let orig = theta[i];
        theta[i] = orig + eps;
        let lp = loss_of(&theta);
        theta[i] = orig - eps;
        let lm = loss_of(&theta);
        theta[i] = orig;
        let fd = (lp - lm) / (2.0 * eps);
        let err = (fd - g[i]).abs();
        worst = worst.max(err - 1e-2 * g[i].abs());
        assert!(
            err <= 2e-3 + 1e-2 * g[i].abs(),
            "param {i}: analytic {} vs finite-diff {fd} (err {err})",
            g[i]
        );
    }
    assert!(worst.is_finite());
}

/// Property: each stage's backward writes *every* element of exactly its
/// own arena stage run — poison the model-wide scratch with a sentinel,
/// run the backward chain, and check the written/untouched split per
/// view.
#[test]
fn native_backward_lands_exactly_in_arena_views() {
    let rt = NativeBackend::synthetic(NativeMlpConfig::tiny());
    let layout = ArenaLayout::from_manifest(rt.manifest());
    let flat = rt.init_params_flat().unwrap();
    let data = cyclic_dp::data::DataSource::from_manifest(rt.manifest());
    let cyclic_dp::data::MicroBatch::Class { x, labels } = data.microbatch(1, 0) else {
        panic!("classification bundle")
    };
    const SENTINEL: f32 = 1.234_567_9e30;

    let mut exec = rt.executor(cyclic_dp::coordinator::ExecMode::HostLiteral);
    let a1 = rt
        .stage_fwd_flat(0, &flat[layout.stage_range(0)], &HostTensor::F32(x.clone()))
        .unwrap();

    // backward into stage 1's run only: stage 0's run must stay poisoned
    let mut g = vec![SENTINEL; layout.total_len];
    let (_, gx) = rt
        .last_bwd(
            &mut exec,
            0,
            &flat[layout.stage_range(1)],
            &HostTensor::F32(a1),
            &labels,
            &mut g[layout.stage_range(1)],
        )
        .unwrap();
    assert!(
        g[layout.stage_range(1)].iter().all(|v| *v != SENTINEL),
        "loss-stage backward must write every element of its stage run"
    );
    assert!(
        g[layout.stage_range(0)].iter().all(|v| *v == SENTINEL),
        "loss-stage backward must not touch other stages"
    );
    // per-view: every tensor view of stage 1 is fully written and finite
    for v in &layout.stages[1].views {
        let base = layout.stage_offsets[1] + v.offset;
        assert!(g[base..base + v.len].iter().all(|x| x.is_finite()));
    }

    // now stage 0
    rt.first_bwd(
        &mut exec,
        0,
        &flat[layout.stage_range(0)],
        &HostTensor::F32(x),
        &gx,
        &mut g[layout.stage_range(0)],
    )
    .unwrap();
    assert!(
        g[layout.stage_range(0)].iter().all(|v| *v != SENTINEL && v.is_finite()),
        "stage-0 backward must write every element of its stage run"
    );
}

// ----------------------------------------------------------- construction --
#[test]
fn unknown_bundle_is_a_clean_error_with_hint() {
    let err = NativeBackend::load_or_synthetic("no_such_bundle").unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("mlp"), "error should explain family support: {msg}");
}

#[test]
fn synthetic_mlp_matches_python_bundle_hyperparams() {
    let rt = native();
    let m = rt.manifest();
    assert_eq!(m.family, "mlp");
    assert_eq!((m.lr, m.momentum), (0.01, 0.9));
    assert_eq!(m.n_stages, m.n_microbatches, "paper: N stages == N micro-batches");
}

// ------------------------------------------------- cross-backend (xla on) --
/// Native vs XLA on the *same* on-disk mlp bundle (same manifest + same
/// θ_0 from params.bin): loss sequences agree to kernel-accumulation
/// tolerance.  Bit-identity is promised *within* a backend, not across —
/// XLA fuses its f32 reductions differently than `tensor::ops` does.
#[cfg(feature = "xla")]
#[test]
fn native_matches_xla_losses_on_shared_bundle() {
    let dir = cyclic_dp::model::artifacts_root().join("mlp");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: mlp bundle missing — run `make artifacts`");
        return;
    }
    let nat = NativeBackend::load(&dir).unwrap();
    let xla = cyclic_dp::runtime::BundleRuntime::load(&dir).unwrap();
    for rule in RULES {
        let a = host_losses(&nat, rule.clone(), 3);
        let mut t = single::RefTrainer::new(&xla, rule.clone()).unwrap();
        let b: Vec<f64> = t.train(3).unwrap().iter().map(|l| l.loss).collect();
        for (step, (x, y)) in a.iter().zip(&b).enumerate() {
            let rel = (x - y).abs() / y.abs().max(1e-9);
            assert!(
                rel < 1e-3,
                "{} step {step}: native {x} vs xla {y} (rel {rel:.2e})",
                rule.name()
            );
        }
    }
}
