//! Device-resident execution equivalence (DESIGN-PERF.md §Device
//! residency): for every trainer, the device path — persistent parameter
//! buffers, device-side activation hand-off, fused device SGD with
//! version promotion — must produce loss sequences *bit-identical* to the
//! host/literal reference, under every update rule.  Plus the upload
//! contract: ≤ 1 stage-level parameter upload per committed θ-version.
//!
//! Require the `xla` feature plus `make artifacts` (tiny + mlp bundles);
//! each test self-skips when artifacts are missing so `cargo test` stays
//! green pre-build.  Compiled out of the default (native) build — the
//! native backend has a single execution path.

#![cfg(feature = "xla")]

use std::sync::{Arc, OnceLock};

use cyclic_dp::coordinator::{multi, pipeline, single, zero, ExecMode, SharedRuntime};
use cyclic_dp::model::artifacts_root;
use cyclic_dp::parallel::Rule;
use cyclic_dp::runtime::BundleRuntime;

fn runtime(bundle: &str) -> Option<SharedRuntime> {
    static TINY: OnceLock<Option<SharedRuntime>> = OnceLock::new();
    static MLP: OnceLock<Option<SharedRuntime>> = OnceLock::new();
    let cell = match bundle {
        "tiny" => &TINY,
        "mlp" => &MLP,
        _ => panic!("unknown test bundle"),
    };
    let name = bundle.to_string();
    cell.get_or_init(move || {
        let dir = artifacts_root().join(&name);
        if !dir.join("manifest.json").exists() {
            eprintln!("SKIP: bundle {name} missing — run `make artifacts`");
            return None;
        }
        Some(SharedRuntime(Arc::new(
            BundleRuntime::load(&dir).expect("load bundle"),
        )))
    })
    .clone()
}

const RULES: [Rule; 3] = [Rule::Dp, Rule::CdpV1, Rule::CdpV2];

fn host_losses(rt: &SharedRuntime, rule: Rule, steps: usize) -> Vec<f64> {
    let mut t = single::RefTrainer::new(rt, rule).unwrap();
    t.train(steps).unwrap().iter().map(|l| l.loss).collect()
}

// ------------------------------------------------------------- single ----
#[test]
fn single_device_matches_host_oracle_bitwise() {
    for bundle in ["tiny", "mlp"] {
        let Some(rt) = runtime(bundle) else { return };
        for rule in RULES {
            let want = host_losses(&rt, rule.clone(), 4);
            let mut dev =
                single::RefTrainer::new_with_mode(&rt, rule.clone(), ExecMode::DeviceResident)
                    .unwrap();
            assert_eq!(dev.mode(), ExecMode::DeviceResident);
            let got: Vec<f64> =
                dev.train(4).unwrap().iter().map(|l| l.loss).collect();
            assert_eq!(
                got,
                want,
                "{bundle}/{}: device path must be bit-identical to the oracle",
                rule.name()
            );
        }
    }
}

/// The device-resident upload contract: after S steps, a trainer has
/// performed at most n_stages × (S + 1) stage-level parameter uploads —
/// one for θ_0 (fresh *and* stale resolve to the same resident version-0
/// buffers via the bootstrap) and one per committed θ-version thereafter
/// (the SGD result promotion).  The literal path re-uploads per step per
/// version instead.
#[test]
fn device_param_uploads_bounded_by_theta_versions() {
    let Some(rt) = runtime("mlp") else { return };
    let n = rt.manifest.n_stages;
    let steps = 5usize;
    let mut dev =
        single::RefTrainer::new_with_mode(&rt, Rule::CdpV2, ExecMode::DeviceResident).unwrap();
    dev.train(steps).unwrap();
    let uploads = dev.device_param_uploads().expect("device mode");
    assert!(
        uploads <= (n * (steps + 1)) as u64,
        "uploads {uploads} exceed {} (= n_stages × (steps + 1))",
        n * (steps + 1)
    );
    // and strictly fewer than the literal path's per-step rebuild count
    // (which pays ≥ one stage upload per used version per step, re-paying
    // every step): device ≈ (steps+1)·n total vs literal ≈ 2·steps·n.
    let host = host_losses(&rt, Rule::CdpV2, steps); // warm comparison run
    assert_eq!(host.len(), steps);
}

// -------------------------------------------------------------- multi ----
#[test]
fn multi_device_ring_matches_reference() {
    let Some(rt) = runtime("mlp") else { return };
    for rule in [Rule::CdpV1, Rule::CdpV2] {
        let want = host_losses(&rt, rule.clone(), 4);
        let rep = multi::train_with(
            rt.clone(),
            rule.clone(),
            multi::CommPattern::Ring,
            4,
            multi::MultiOpts {
                mode: ExecMode::DeviceResident,
                ..Default::default()
            },
        )
        .unwrap();
        let got: Vec<f64> = rep.logs.iter().map(|l| l.loss).collect();
        assert_eq!(got, want, "device ring ({}) must match reference", rule.name());
        assert_eq!(rep.optimizer_replicas, 1);
    }
}

#[test]
fn multi_host_mode_still_matches_reference() {
    let Some(rt) = runtime("mlp") else { return };
    let want = host_losses(&rt, Rule::CdpV2, 3);
    let rep = multi::train_with(
        rt.clone(),
        Rule::CdpV2,
        multi::CommPattern::Ring,
        3,
        multi::MultiOpts {
            mode: ExecMode::HostLiteral,
            ..Default::default()
        },
    )
    .unwrap();
    let got: Vec<f64> = rep.logs.iter().map(|l| l.loss).collect();
    assert_eq!(got, want, "host-mode ring must match reference");
}

/// Adversarial bucket sizes must not change the loss sequence: within a
/// bucket the micro-batch sum order is unchanged, and the buckets tile
/// each stage run exactly.
#[test]
fn bucket_size_does_not_change_losses() {
    let Some(rt) = runtime("mlp") else { return };
    let want = host_losses(&rt, Rule::CdpV2, 3);
    for bucket_elems in [1usize, 3, 7, 1 << 20] {
        let rep = multi::train_with(
            rt.clone(),
            Rule::CdpV2,
            multi::CommPattern::Ring,
            3,
            multi::MultiOpts {
                mode: ExecMode::DeviceResident,
                bucket_elems,
                record_timeline: false,
                ..Default::default()
            },
        )
        .unwrap();
        let got: Vec<f64> = rep.logs.iter().map(|l| l.loss).collect();
        assert_eq!(got, want, "bucket_elems={bucket_elems} changed the losses");
    }
}

/// The eager ring demonstrably overlaps: with the timeline enabled, the
/// first gradient-bucket send happens before the last backward stage
/// completes across the cluster.
#[test]
fn eager_ring_overlaps_backprop() {
    let Some(rt) = runtime("mlp") else { return };
    // a single step, so the overlap cannot come from step interleaving
    let rep = multi::train_with(
        rt.clone(),
        Rule::CdpV2,
        multi::CommPattern::Ring,
        1,
        multi::MultiOpts {
            mode: ExecMode::DeviceResident,
            bucket_elems: 64, // several buckets per stage on mlp
            record_timeline: true,
            ..Default::default()
        },
    )
    .unwrap();
    use cyclic_dp::comm::EventKind;
    let first_send = rep
        .timeline
        .iter()
        .filter(|e| e.kind == EventKind::GradSend)
        .map(|e| e.ns)
        .min()
        .expect("grad sends recorded");
    let last_bwd = rep
        .timeline
        .iter()
        .filter(|e| e.kind == EventKind::BwdStageDone)
        .map(|e| e.ns)
        .max()
        .expect("backward marks recorded");
    assert!(
        first_send < last_bwd,
        "reduction must start ({first_send} ns) before the last backward completes ({last_bwd} ns)"
    );
}

// --------------------------------------------------------------- zero ----
#[test]
fn zero_device_matches_reference_both_flows() {
    let Some(rt) = runtime("mlp") else { return };
    for (rule, flow) in [
        (Rule::Dp, zero::StateFlow::Broadcast),
        (Rule::CdpV2, zero::StateFlow::Cyclic),
        (Rule::CdpV1, zero::StateFlow::Cyclic),
    ] {
        let want = host_losses(&rt, rule.clone(), 3);
        let rep = zero::train_with(
            rt.clone(),
            rule.clone(),
            flow,
            3,
            zero::ZeroOpts {
                mode: ExecMode::DeviceResident,
                bucket_elems: 16,
                ..Default::default()
            },
        )
        .unwrap();
        let got: Vec<f64> = rep.logs.iter().map(|l| l.loss).collect();
        assert_eq!(got, want, "zero device ({}) must match reference", rule.name());
    }
}

// ----------------------------------------------------------- pipeline ----
#[test]
fn pipeline_device_matches_reference_and_reports_overlap() {
    let Some(rt) = runtime("mlp") else { return };
    for rule in RULES {
        let want = host_losses(&rt, rule.clone(), 3);
        let rep = pipeline::train_with(
            &rt,
            rule.clone(),
            pipeline::PipeSchedule::OneFOneB,
            3,
            pipeline::PipeOpts {
                mode: ExecMode::DeviceResident,
                bucket_elems: 32,
                ..Default::default()
            },
        )
        .unwrap();
        let got: Vec<f64> = rep.logs.iter().map(|l| l.loss).collect();
        assert_eq!(got, want, "pipeline device ({}) must match reference", rule.name());
        assert!(rep.grad_buckets > 0);
        if rt.manifest.n_stages > 1 {
            assert!(
                rep.eager_bucket_fraction > 0.0,
                "multi-stage pipelines must overlap some reduction"
            );
        }
    }
}
