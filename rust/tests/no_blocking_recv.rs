//! Grep-shim enforcing the deadline contract (DESIGN-ROBUSTNESS.md):
//! no blocking receive without a deadline, and none of the silent-hang
//! `expect` sites the seed fabric had, anywhere in the comm or
//! coordinator layers.  Source-text scanning is crude but it is the one
//! check that cannot be dodged by a new call site: the only raw
//! `Receiver::recv()` in the tree is the deadline-looped one inside
//! `Endpoint::recv_deadline`.

use std::fs;
use std::path::{Path, PathBuf};

fn rust_sources(dir: &Path, out: &mut Vec<PathBuf>) {
    for entry in fs::read_dir(dir).unwrap() {
        let path = entry.unwrap().path();
        if path.is_dir() {
            rust_sources(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

/// Strip the scanner's own exemption: the single raw `rx.recv_timeout`
/// loop lives in `Endpoint::recv_deadline`, every other receive must go
/// through `recv`/`recv_deadline` (which carry deadlines and typed
/// errors).
#[test]
fn no_blocking_receive_without_a_deadline() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let mut files = Vec::new();
    for sub in ["comm", "coordinator"] {
        rust_sources(&root.join(sub), &mut files);
    }
    assert!(files.len() >= 8, "scanner found too few files — wrong root?");

    // needles are split so this file does not match itself when the
    // scanner ever widens to tests/
    let raw_recv = format!("rx.{}()", "recv");
    let hang_a = format!("expect(\"{}\")", "fabric closed");
    let hang_b = format!("expect(\"{}\")", "peer endpoint dropped");

    let mut offenders = Vec::new();
    for path in &files {
        let text = fs::read_to_string(path).unwrap();
        for (lineno, line) in text.lines().enumerate() {
            let trimmed = line.trim_start();
            if trimmed.starts_with("//") {
                continue;
            }
            if line.contains(&raw_recv)
                || line.contains(&hang_a)
                || line.contains(&hang_b)
            {
                offenders.push(format!("{}:{}: {}", path.display(), lineno + 1, line.trim()));
            }
        }
    }
    assert!(
        offenders.is_empty(),
        "blocking receives without deadlines (or seed-era hang sites) found:\n{}",
        offenders.join("\n")
    );
}

/// The hot paths may not unwrap a channel operation either: a worker
/// death must surface as a typed `CommError`/`anyhow` context, never a
/// panic in a random peer.  `unwrap()` on locks/joins is fine — those
/// are process-local invariants — so the scan is scoped to comm calls.
#[test]
fn comm_results_are_not_unwrapped_in_coordinators() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src").join("coordinator");
    let mut files = Vec::new();
    rust_sources(&root, &mut files);

    let mut offenders = Vec::new();
    for path in &files {
        let text = fs::read_to_string(path).unwrap();
        let mut in_tests = false;
        for (lineno, line) in text.lines().enumerate() {
            if line.contains("mod tests") {
                in_tests = true; // unwraps are fine in test code
            }
            if in_tests {
                continue;
            }
            let t = line.trim_start();
            if t.starts_with("//") {
                continue;
            }
            for call in [".send(", ".send_copy(", ".recv(", ".recv_deadline("] {
                if line.contains(call)
                    && (line.contains(".unwrap()") || line.contains(".expect("))
                {
                    offenders.push(format!(
                        "{}:{}: {}",
                        path.display(),
                        lineno + 1,
                        line.trim()
                    ));
                }
            }
        }
    }
    assert!(
        offenders.is_empty(),
        "comm calls unwrapped on coordinator hot paths:\n{}",
        offenders.join("\n")
    );
}
