//! Fault-tolerance integration suite (DESIGN-ROBUSTNESS.md): the
//! kill-and-resume contract on every trainer, loss equivalence under a
//! seeded lossy fabric, and the multi ring's graceful N−1 degradation
//! after a scripted worker kill.
//!
//! Everything here runs on the pure-Rust [`NativeBackend`] — no
//! artifacts, no network — and every equivalence is *bit*-identical
//! (`f64` losses compared with `==`), not approximate: checkpoints
//! capture complete optimizer state at θ-version boundaries, the data
//! stream is a pure function of `(seed, step, mb)`, and fault recovery
//! re-delivers the original payload bytes.

use std::sync::Arc;

use cyclic_dp::comm::FaultPlan;
use cyclic_dp::coordinator::{multi, pipeline, single, zero, SharedBackend};
use cyclic_dp::parallel::{ArenaLayout, Checkpoint, Rule};
use cyclic_dp::runtime::{NativeBackend, NativeMlpConfig};

fn native() -> NativeBackend {
    NativeBackend::default_mlp()
}

fn losses(logs: &[cyclic_dp::coordinator::StepLog]) -> Vec<f64> {
    logs.iter().map(|l| l.loss).collect()
}

/// Serialize + deserialize: every resume below goes through the wire
/// format, so the tests cover `to_bytes`/`from_bytes` as well as the
/// in-memory round trip.
fn through_wire(ck: Checkpoint) -> Checkpoint {
    Checkpoint::from_bytes(&ck.to_bytes()).expect("wire round trip")
}

// ---------------------------------------------- kill/resume, bit-identical --
// Contract: run K steps, checkpoint, "kill" the process (here: drop all
// state), resume from the serialized checkpoint, run the remaining
// steps — the concatenated losses equal the uninterrupted run's.

#[test]
fn single_kill_resume_is_bit_identical() {
    for rule in [Rule::Dp, Rule::CdpV1, Rule::CdpV2] {
        let rt = native();
        let mut clean = single::RefTrainer::new(&rt, rule.clone()).unwrap();
        let want = losses(&clean.train(6).unwrap());

        let mut head = single::RefTrainer::new(&rt, rule.clone()).unwrap();
        let mut got = losses(&head.train(3).unwrap());
        let ck = through_wire(head.checkpoint());
        drop(head); // the "kill"

        let mut tail = single::RefTrainer::resume(&rt, rule.clone(), ck).unwrap();
        got.extend(losses(&tail.train(3).unwrap()));
        assert_eq!(got, want, "single ({}) resume diverged", rule.name());
    }
}

#[test]
fn multi_kill_resume_is_bit_identical_for_both_patterns() {
    let shared = SharedBackend(Arc::new(native()));
    for (rule, pattern) in [
        (Rule::Dp, multi::CommPattern::Barrier),
        (Rule::CdpV2, multi::CommPattern::Ring),
        (Rule::CdpV1, multi::CommPattern::Ring),
    ] {
        let want = losses(
            &multi::train(shared.clone(), rule.clone(), pattern, 6).unwrap().logs,
        );

        let head = multi::train_with(
            shared.clone(),
            rule.clone(),
            pattern,
            3,
            multi::MultiOpts { checkpoint_at: Some(2), ..Default::default() },
        )
        .unwrap();
        let ck = through_wire(head.checkpoint.expect("checkpoint captured"));
        assert_eq!(ck.step, 3, "boundary after step 2 is θ-version 3");

        let tail = multi::resume_with(
            shared.clone(),
            rule.clone(),
            pattern,
            3,
            multi::MultiOpts::default(),
            ck,
        )
        .unwrap();
        let mut got = losses(&head.logs);
        got.extend(losses(&tail.logs));
        assert_eq!(got, want, "multi {pattern:?} ({}) resume diverged", rule.name());
    }
}

#[test]
fn zero_kill_resume_is_bit_identical_for_both_flows() {
    let shared = SharedBackend(Arc::new(native()));
    for (rule, flow) in [
        (Rule::Dp, zero::StateFlow::Broadcast),
        (Rule::CdpV2, zero::StateFlow::Cyclic),
    ] {
        let want =
            losses(&zero::train(shared.clone(), rule.clone(), flow, 6).unwrap().logs);

        let head = zero::train_with(
            shared.clone(),
            rule.clone(),
            flow,
            3,
            zero::ZeroOpts { checkpoint_at: Some(2), ..Default::default() },
        )
        .unwrap();
        let ck = through_wire(head.checkpoint.expect("checkpoint gathered to worker 0"));

        let tail = zero::resume_with(
            shared.clone(),
            rule.clone(),
            flow,
            3,
            zero::ZeroOpts::default(),
            ck,
        )
        .unwrap();
        let mut got = losses(&head.logs);
        got.extend(losses(&tail.logs));
        assert_eq!(got, want, "zero {flow:?} ({}) resume diverged", rule.name());
    }
}

#[test]
fn pipeline_kill_resume_is_bit_identical_for_both_schedules() {
    let rt = native();
    for (rule, sched) in [
        (Rule::CdpV2, pipeline::PipeSchedule::OneFOneB),
        (Rule::Dp, pipeline::PipeSchedule::GPipe),
    ] {
        let want = losses(&pipeline::train(&rt, rule.clone(), sched, 6).unwrap().logs);

        let head = pipeline::train_with(
            &rt,
            rule.clone(),
            sched,
            3,
            pipeline::PipeOpts { checkpoint_at: Some(2), ..Default::default() },
        )
        .unwrap();
        let ck = through_wire(head.checkpoint.expect("checkpoint captured"));

        let tail = pipeline::resume_with(
            &rt,
            rule.clone(),
            sched,
            3,
            pipeline::PipeOpts::default(),
            ck,
        )
        .unwrap();
        let mut got = losses(&head.logs);
        got.extend(losses(&tail.logs));
        assert_eq!(got, want, "pipeline {sched:?} ({}) resume diverged", rule.name());
    }
}

/// A checkpoint written under one rule must not silently resume under
/// another: the version-selection schedule is part of the state.
#[test]
fn resume_under_wrong_rule_is_a_typed_error() {
    let rt = native();
    let mut t = single::RefTrainer::new(&rt, Rule::CdpV2).unwrap();
    t.train(2).unwrap();
    let ck = t.checkpoint();
    let Err(err) = single::RefTrainer::resume(&rt, Rule::Dp, ck) else {
        panic!("rule mismatch must fail")
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("cdp_v2") && msg.contains("dp"), "unhelpful error: {msg}");
}

// ------------------------------------------------- lossy fabric, 30 steps --
// Seeded drop/dup/reorder at p = 0.05 on every non-control edge: the
// deadline/retry receive path recovers every message, so 30 training
// steps stay bit-identical to the clean run.

#[test]
fn multi_losses_survive_a_lossy_fabric() {
    let shared = SharedBackend(Arc::new(native()));
    for (rule, pattern) in [
        (Rule::CdpV2, multi::CommPattern::Ring),
        (Rule::Dp, multi::CommPattern::Barrier),
    ] {
        let want = losses(
            &multi::train(shared.clone(), rule.clone(), pattern, 30).unwrap().logs,
        );
        let rep = multi::train_with(
            shared.clone(),
            rule.clone(),
            pattern,
            30,
            multi::MultiOpts {
                faults: Some(FaultPlan::lossy(0xFA_01, 0.05)),
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(
            losses(&rep.logs),
            want,
            "multi {pattern:?} ({}) diverged under faults",
            rule.name()
        );
    }
}

#[test]
fn zero_losses_survive_a_lossy_fabric() {
    let shared = SharedBackend(Arc::new(native()));
    for (rule, flow) in [
        (Rule::CdpV2, zero::StateFlow::Cyclic),
        (Rule::Dp, zero::StateFlow::Broadcast),
    ] {
        let want =
            losses(&zero::train(shared.clone(), rule.clone(), flow, 30).unwrap().logs);
        let rep = zero::train_with(
            shared.clone(),
            rule.clone(),
            flow,
            30,
            zero::ZeroOpts {
                faults: Some(FaultPlan::lossy(0xFA_02, 0.05)),
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(
            losses(&rep.logs),
            want,
            "zero {flow:?} ({}) diverged under faults",
            rule.name()
        );
    }
}

// --------------------------------------------------- graceful degradation --
// Scripted kill of a mid-ring worker: survivors detect the loss at the
// next θ-version boundary, re-form the cyclic ring with N−1 workers and
// keep training.  Post-junction losses are bit-identical to a reference
// trainer on an N−1-micro-batch model resumed from the junction
// checkpoint — the degraded cluster *is* that smaller cluster.

#[test]
fn multi_ring_reforms_with_n_minus_1_after_scripted_kill() {
    const KILL_STEP: u64 = 3;
    let shared = SharedBackend(Arc::new(native()));
    let n = shared.manifest().n_microbatches; // 4
    let rep = multi::train_with(
        shared.clone(),
        Rule::CdpV2,
        multi::CommPattern::Ring,
        6,
        multi::MultiOpts {
            faults: Some(FaultPlan::kill_only(2, KILL_STEP)),
            checkpoint_at: Some(KILL_STEP - 1), // junction boundary
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(rep.logs.len(), 6, "survivors must finish all steps");

    // pre-junction steps match the clean 4-worker run
    let clean = multi::train(shared.clone(), Rule::CdpV2, multi::CommPattern::Ring, 3)
        .unwrap();
    assert_eq!(
        losses(&rep.logs[..KILL_STEP as usize]),
        losses(&clean.logs[..KILL_STEP as usize]),
        "pre-kill steps must be unaffected"
    );

    // post-junction steps match an N−1 reference resumed from the
    // junction: same model (layout depends on stages, not micro-batch
    // count), same data stream, 3 micro-batches per step.
    let ck = through_wire(rep.checkpoint.expect("junction checkpoint"));
    assert_eq!(ck.step, KILL_STEP);
    let rt3 = NativeBackend::synthetic(NativeMlpConfig {
        n_microbatches: n - 1,
        ..NativeMlpConfig::default()
    });
    let mut reference = single::RefTrainer::resume(&rt3, Rule::CdpV2, ck).unwrap();
    let want = losses(&reference.train(3).unwrap());
    assert_eq!(
        losses(&rep.logs[KILL_STEP as usize..]),
        want,
        "degraded ring must equal the fresh N−1 cluster"
    );
}

#[test]
fn kill_plans_are_validated_per_trainer() {
    let shared = SharedBackend(Arc::new(native()));
    let n = shared.manifest().n_microbatches;

    // barrier has no degraded mode
    let Err(err) = multi::train_with(
        shared.clone(),
        Rule::Dp,
        multi::CommPattern::Barrier,
        2,
        multi::MultiOpts {
            faults: Some(FaultPlan::kill_only(1, 1)),
            ..Default::default()
        },
    ) else {
        panic!("barrier kill plan must be rejected")
    };
    assert!(format!("{err:#}").contains("ring"), "{err:#}");

    // structural workers (loss logger, optimizer owner) are not killable
    for w in [0, n - 1] {
        let Err(err) = multi::train_with(
            shared.clone(),
            Rule::CdpV2,
            multi::CommPattern::Ring,
            2,
            multi::MultiOpts {
                faults: Some(FaultPlan::kill_only(w, 1)),
                ..Default::default()
            },
        ) else {
            panic!("structural-worker kill plan must be rejected")
        };
        assert!(format!("{err:#}").contains("killable"), "{err:#}");
    }

    // ZeRO shards the optimizer — a kill takes the only copy of a stage's
    // state with it, so a kill plan without a re-replication source
    // (ZeroOpts::recover_from) is rejected up front
    let Err(err) = zero::train_with(
        shared.clone(),
        Rule::CdpV2,
        zero::StateFlow::Cyclic,
        2,
        zero::ZeroOpts {
            faults: Some(FaultPlan::kill_only(1, 1)),
            ..Default::default()
        },
    ) else {
        panic!("zero kill plan without recover_from must be rejected")
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("recover_from"), "{msg}");

    // and ZeRO's checkpoint assembler (worker 0) is structural
    let Err(err) = zero::train_with(
        shared.clone(),
        Rule::CdpV2,
        zero::StateFlow::Cyclic,
        2,
        zero::ZeroOpts {
            faults: Some(FaultPlan::kill_only(0, 1)),
            recover_from: Some(std::env::temp_dir().join("unused.ckpt")),
            ..Default::default()
        },
    ) else {
        panic!("zero worker-0 kill plan must be rejected")
    };
    assert!(format!("{err:#}").contains("structural"), "{err:#}");
}

/// Kill + lossy edges at once: detection and re-form still converge, and
/// the degraded steps still match the N−1 reference (recovery is exact,
/// not approximate, even while the membership changes).
#[test]
fn degradation_survives_simultaneous_message_faults() {
    const KILL_STEP: u64 = 2;
    let shared = SharedBackend(Arc::new(native()));
    let n = shared.manifest().n_microbatches;
    let rep = multi::train_with(
        shared.clone(),
        Rule::CdpV1,
        multi::CommPattern::Ring,
        5,
        multi::MultiOpts {
            faults: Some(FaultPlan::lossy(0xFA_03, 0.05).with_kill(1, KILL_STEP)),
            checkpoint_at: Some(KILL_STEP - 1),
            ..Default::default()
        },
    )
    .unwrap();
    let ck = through_wire(rep.checkpoint.expect("junction checkpoint"));
    let rt3 = NativeBackend::synthetic(NativeMlpConfig {
        n_microbatches: n - 1,
        ..NativeMlpConfig::default()
    });
    let mut reference = single::RefTrainer::resume(&rt3, Rule::CdpV1, ck).unwrap();
    let want = losses(&reference.train(3).unwrap());
    assert_eq!(losses(&rep.logs[KILL_STEP as usize..]), want);
}

// ------------------------------------------------ zero shard re-replication --
// ZeRO's kill path (DESIGN-ROBUSTNESS.md): survivors heartbeat, freeze at
// the junction when the victim goes silent, and the dead worker's shard
// re-replicates from the persisted checkpoint — the resumed fleet keeps
// full strength and its losses stay bit-identical to an uninterrupted run.

fn tmp_ckpt(label: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("cdp-zero-{label}-{}.ckpt", std::process::id()))
}

#[test]
fn zero_rereplicates_dead_shard_bit_identically() {
    const KILL_STEP: u64 = 3;
    let shared = SharedBackend(Arc::new(native()));
    for (rule, flow, label) in [
        (Rule::CdpV2, zero::StateFlow::Cyclic, "cyc"),
        (Rule::Dp, zero::StateFlow::Broadcast, "bro"),
    ] {
        let want =
            losses(&zero::train(shared.clone(), rule.clone(), flow, 6).unwrap().logs);
        let path = tmp_ckpt(label);
        let rep = zero::train_with(
            shared.clone(),
            rule.clone(),
            flow,
            6,
            zero::ZeroOpts {
                faults: Some(FaultPlan::kill_only(2, KILL_STEP)),
                checkpoint_at: Some(KILL_STEP - 1), // junction boundary
                save_checkpoint_to: Some(path.clone()),
                recover_from: Some(path.clone()),
                ..Default::default()
            },
        )
        .unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(rep.logs.len(), 6, "re-replicated fleet must finish all steps");
        assert_eq!(
            losses(&rep.logs),
            want,
            "zero {flow:?} ({}) re-replication diverged",
            rule.name()
        );
    }
}

/// Kill + lossy data plane at once: detection, freeze and the phase-2
/// resume still converge bit-identically (recovery composes with
/// retry + seq-dedup rather than fighting it).
#[test]
fn zero_rereplication_survives_simultaneous_message_faults() {
    const KILL_STEP: u64 = 2;
    let shared = SharedBackend(Arc::new(native()));
    let want =
        losses(&zero::train(shared.clone(), Rule::CdpV2, zero::StateFlow::Cyclic, 5)
            .unwrap()
            .logs);
    let path = tmp_ckpt("lossy");
    let rep = zero::train_with(
        shared.clone(),
        Rule::CdpV2,
        zero::StateFlow::Cyclic,
        5,
        zero::ZeroOpts {
            faults: Some(FaultPlan::lossy(0xFA_04, 0.05).with_kill(3, KILL_STEP)),
            checkpoint_at: Some(KILL_STEP - 1),
            save_checkpoint_to: Some(path.clone()),
            recover_from: Some(path.clone()),
            ..Default::default()
        },
    )
    .unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(losses(&rep.logs), want);
}

#[test]
fn zero_kill_without_covering_checkpoint_is_a_typed_error() {
    let shared = SharedBackend(Arc::new(native()));
    let path = tmp_ckpt("missing");
    let _ = std::fs::remove_file(&path);
    let err = zero::train_with(
        shared,
        Rule::CdpV2,
        zero::StateFlow::Cyclic,
        4,
        zero::ZeroOpts {
            faults: Some(FaultPlan::kill_only(1, 2)),
            recover_from: Some(path.clone()),
            ..Default::default()
        },
    )
    .unwrap_err();
    match err.downcast_ref::<zero::ShardRecoveryError>() {
        Some(zero::ShardRecoveryError::NoCheckpoint { path: p }) => assert_eq!(p, &path),
        other => panic!("want NoCheckpoint, got {other:?} ({err:#})"),
    }
}

#[test]
fn zero_stale_checkpoint_is_a_typed_error() {
    let shared = SharedBackend(Arc::new(native()));
    let path = tmp_ckpt("stale");
    let err = zero::train_with(
        shared,
        Rule::CdpV2,
        zero::StateFlow::Cyclic,
        5,
        zero::ZeroOpts {
            faults: Some(FaultPlan::kill_only(1, 3)),
            checkpoint_at: Some(0), // boundary 1; the junction is 3
            save_checkpoint_to: Some(path.clone()),
            recover_from: Some(path.clone()),
            ..Default::default()
        },
    )
    .unwrap_err();
    match err.downcast_ref::<zero::ShardRecoveryError>() {
        Some(zero::ShardRecoveryError::StaleCheckpoint { found, needed, .. }) => {
            assert_eq!((*found, *needed), (1, 3));
        }
        other => panic!("want StaleCheckpoint, got {other:?} ({err:#})"),
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn recover_shard_rejects_uncovered_stage_and_wrong_junction() {
    let shared = SharedBackend(Arc::new(native()));
    let path = tmp_ckpt("uncov");
    zero::train_with(
        shared.clone(),
        Rule::CdpV2,
        zero::StateFlow::Cyclic,
        2,
        zero::ZeroOpts {
            checkpoint_at: Some(1),
            save_checkpoint_to: Some(path.clone()),
            ..Default::default()
        },
    )
    .unwrap();
    let layout = ArenaLayout::from_manifest(shared.manifest());
    let n = shared.manifest().n_stages;

    let err = zero::recover_shard(&path, &layout, &Rule::CdpV2, n + 3, 2).unwrap_err();
    assert!(matches!(err, zero::ShardRecoveryError::ShardUncovered { .. }), "{err}");

    let err = zero::recover_shard(&path, &layout, &Rule::CdpV2, 1, 99).unwrap_err();
    assert!(matches!(err, zero::ShardRecoveryError::StaleCheckpoint { .. }), "{err}");

    let err = zero::recover_shard(&path, &layout, &Rule::Dp, 1, 2).unwrap_err();
    assert!(matches!(err, zero::ShardRecoveryError::Invalid { .. }), "{err}");

    let shard = zero::recover_shard(&path, &layout, &Rule::CdpV2, 1, 2).unwrap();
    assert_eq!(shard.cur.len(), layout.stage_range(1).len());
    let _ = std::fs::remove_file(&path);
}
