//! True multi-process equivalence: launch a fleet of real `cdp` worker
//! processes (one OS process per worker, rendezvousing over UDS or TCP)
//! and require worker 0's per-step losses to be bit-identical to the
//! single-process, in-process-channel trainer.  Losses cross the process
//! boundary as `CDP_LOSS <step> <f64-bits-hex>` lines, so the comparison
//! is on bits, never on printf-rounded text.

use std::path::PathBuf;
use std::sync::Arc;

use cyclic_dp::cluster::launch::{launch, merge_traces, parse_loss_bits, LaunchSpec};
use cyclic_dp::comm::WireKind;
use cyclic_dp::coordinator::{multi, zero, SharedBackend, StepLog};
use cyclic_dp::parallel::Rule;
use cyclic_dp::runtime::NativeBackend;
use cyclic_dp::trace::{render_loss_line, verify, TraceKind, VerifyOpts};

const STEPS: usize = 3;

fn shared() -> SharedBackend<NativeBackend> {
    SharedBackend(Arc::new(NativeBackend::default_mlp()))
}

/// Launch `n` worker processes for `trainer` and return worker 0's
/// `(step, loss)` pairs.
fn fleet(trainer: &str, kind: WireKind, label: &str) -> Vec<(u64, f64)> {
    let dir = std::env::temp_dir().join(format!(
        "cdp-proc-{label}-{}",
        std::process::id()
    ));
    let n = shared().manifest().n_microbatches;
    let spec = LaunchSpec {
        workers: n,
        transport: kind,
        rendezvous: dir.clone(),
        exe: Some(PathBuf::from(env!("CARGO_BIN_EXE_cdp"))),
        forward: vec![
            "--trainer".into(),
            trainer.into(),
            "--rule".into(),
            "cdp_v2".into(),
            "--steps".into(),
            STEPS.to_string(),
        ],
    };
    let result = launch(&spec);
    std::fs::remove_dir_all(&dir).ok();
    let outs = result.unwrap_or_else(|e| panic!("launch failed: {e:#}"));
    parse_loss_bits(&String::from_utf8_lossy(&outs[0].stdout))
        .unwrap_or_else(|e| panic!("bad worker-0 stdout: {e:#}"))
}

fn assert_bit_identical(got: &[(u64, f64)], want: &[StepLog]) {
    assert_eq!(got.len(), want.len(), "step count across processes");
    for (log, (step, loss)) in want.iter().zip(got) {
        assert_eq!(*step, log.step);
        assert_eq!(
            loss.to_bits(),
            log.loss.to_bits(),
            "step {step}: process fleet diverged from in-process run"
        );
    }
}

#[test]
fn multi_worker_processes_over_uds_match_the_in_process_fabric() {
    let want = multi::train(shared(), Rule::CdpV2, multi::CommPattern::Ring, STEPS)
        .unwrap()
        .logs;
    let got = fleet("multi", WireKind::Uds, "multi-uds");
    assert_bit_identical(&got, &want);
}

#[test]
fn multi_worker_processes_over_tcp_match_the_in_process_fabric() {
    let want = multi::train(shared(), Rule::CdpV2, multi::CommPattern::Ring, STEPS)
        .unwrap()
        .logs;
    let got = fleet("multi", WireKind::Tcp, "multi-tcp");
    assert_bit_identical(&got, &want);
}

#[test]
fn zero_worker_processes_over_uds_match_the_in_process_fabric() {
    let want = zero::train(shared(), Rule::CdpV2, zero::StateFlow::Cyclic, STEPS)
        .unwrap()
        .logs;
    let got = fleet("zero", WireKind::Uds, "zero-uds");
    assert_bit_identical(&got, &want);
}

#[test]
fn traced_fleet_loss_events_bit_match_the_stdout_protocol() {
    // Per-process tracing: each worker writes trace-w{id}.jsonl into the
    // rendezvous dir; the launcher-side merge must yield a stream whose
    // worker-0 Loss events *are* the CDP_LOSS stdout lines (the stdout
    // protocol is derived from the trace event, so they agree by
    // construction — this proves the plumbing end to end), and the
    // merged fleet trace must still satisfy the cyclic invariants.
    let dir = std::env::temp_dir().join(format!("cdp-proc-traced-{}", std::process::id()));
    let n = shared().manifest().n_microbatches;
    let spec = LaunchSpec {
        workers: n,
        transport: WireKind::Uds,
        rendezvous: dir.clone(),
        exe: Some(PathBuf::from(env!("CARGO_BIN_EXE_cdp"))),
        forward: vec![
            "--trainer".into(),
            "multi".into(),
            "--rule".into(),
            "cdp_v2".into(),
            "--steps".into(),
            STEPS.to_string(),
            "--trace-dir".into(),
            dir.to_string_lossy().into_owned(),
        ],
    };
    let result = launch(&spec);
    let merged = merge_traces(&dir, n);
    std::fs::remove_dir_all(&dir).ok();
    let outs = result.unwrap_or_else(|e| panic!("traced launch failed: {e:#}"));
    let merged = merged.unwrap_or_else(|e| panic!("merge failed: {e:#}"));

    let stdout = String::from_utf8_lossy(&outs[0].stdout);
    let lines: Vec<&str> = stdout.lines().filter(|l| l.starts_with("CDP_LOSS ")).collect();
    let loss_events: Vec<_> = merged
        .events
        .iter()
        .filter(|e| e.kind == TraceKind::Loss && e.worker == 0)
        .collect();
    assert_eq!(lines.len(), STEPS, "one stdout loss line per step");
    assert_eq!(loss_events.len(), STEPS, "one traced loss event per step");
    for (ev, line) in loss_events.iter().zip(&lines) {
        assert_eq!(
            render_loss_line(ev).as_deref(),
            Some(*line),
            "stdout protocol and trace stream must be the same event"
        );
    }

    // fleet traces carry the wire layer too
    assert!(merged.events.iter().any(|e| e.kind == TraceKind::FrameSend));
    assert!(merged.events.iter().any(|e| e.kind == TraceKind::FrameRecv));
    let r = verify(&merged.events, &VerifyOpts::default());
    assert!(r.mem.evaluated && r.balance.evaluated, "{r:?}");
    assert!(r.ok, "merged fleet trace must verify: {r:?}");
}
