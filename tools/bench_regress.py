#!/usr/bin/env python3
"""Bench regression gate for BENCH_*.json reports.

Usage:
    bench_regress.py CURRENT.json BASELINE.json [--threshold=0.25]
    bench_regress.py CURRENT.json BASELINE.json --counter=NAME [--slack=0.25]

Default mode compares the ``timings`` arrays of two reports produced by
the bench harness (``rust/benches/harness.rs``::write_json).  For every
label present in *both* files, fails if the current ``mean_ns`` exceeds
the baseline by more than the threshold (default +25%).  Labels only
present on one side are reported but never fail the gate — benches grow
sections over time and the baseline lags by design.

``--counter=NAME`` instead gates a single named scalar from the
``counters`` object with an *absolute* slack (default 0.25): fails when
``current > baseline + slack``.  Counters like the planner's
``planner_pick_regret`` are legitimately 0.0 at baseline, where a
relative ratio is meaningless — the absolute-slack form is the right
contract for them.  A counter missing from the baseline is seeded (skip,
exit 0); missing from the current report is an error.

The script self-skips (exit 0, with a notice) when the baseline file
does not exist: the first green CI run on quiet hardware seeds the
baseline, which is then committed at ``rust/bench_baselines/``.

Exit codes: 0 ok/skipped, 1 regression, 2 usage or malformed input.
Stdlib only — no third-party dependencies.
"""

import json
import sys


def load_report(path):
    with open(path, encoding="utf-8") as f:
        return json.load(f)


def load_timings(path):
    report = load_report(path)
    timings = report.get("timings")
    if not isinstance(timings, list):
        raise ValueError(f"{path}: no 'timings' array")
    out = {}
    for t in timings:
        label, mean = t.get("label"), t.get("mean_ns")
        if not isinstance(label, str) or not isinstance(mean, (int, float)):
            raise ValueError(f"{path}: malformed timing entry {t!r}")
        out[label] = float(mean)
    return report.get("git_sha", "unknown"), out


def load_counter(path, name):
    report = load_report(path)
    counters = report.get("counters")
    if not isinstance(counters, dict):
        raise ValueError(f"{path}: no 'counters' object")
    value = counters.get(name)
    if value is not None and not isinstance(value, (int, float)):
        raise ValueError(f"{path}: counter {name!r} is not a number: {value!r}")
    return report.get("git_sha", "unknown"), value


def fmt_ns(ns):
    if ns < 1e3:
        return f"{ns:.0f}ns"
    if ns < 1e6:
        return f"{ns / 1e3:.2f}us"
    if ns < 1e9:
        return f"{ns / 1e6:.2f}ms"
    return f"{ns / 1e9:.2f}s"


def gate_counter(current_path, baseline_path, name, slack):
    try:
        cur_sha, cur = load_counter(current_path, name)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"bench_regress: cannot read current report: {e}", file=sys.stderr)
        return 2
    if cur is None:
        print(
            f"bench_regress: counter {name!r} missing from {current_path}",
            file=sys.stderr,
        )
        return 2
    try:
        base_sha, base = load_counter(baseline_path, name)
    except FileNotFoundError:
        print(
            f"bench_regress: no baseline at {baseline_path} — skipping "
            "(commit a green run's report there to arm the gate)"
        )
        return 0
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"bench_regress: cannot read baseline: {e}", file=sys.stderr)
        return 2
    if base is None:
        print(
            f"bench_regress: counter {name!r} not in baseline yet — skipping "
            "(re-seed the baseline to arm it)"
        )
        return 0

    limit = base + slack
    print(
        f"bench_regress: counter {name!r}, current {cur_sha[:12]} vs "
        f"baseline {base_sha[:12]}: {cur:.4f} vs {base:.4f} "
        f"(limit {limit:.4f} = baseline + {slack})"
    )
    if cur > limit:
        print(
            f"bench_regress: FAIL — counter {name!r} rose from {base:.4f} "
            f"to {cur:.4f}, beyond the +{slack} absolute slack",
            file=sys.stderr,
        )
        return 1
    print("bench_regress: OK")
    return 0


def main(argv):
    args = [a for a in argv[1:] if not a.startswith("--")]
    threshold = 0.25
    slack = 0.25
    counter = None
    for a in argv[1:]:
        if a.startswith("--threshold="):
            try:
                threshold = float(a.split("=", 1)[1])
            except ValueError:
                print("bench_regress: bad --threshold", file=sys.stderr)
                return 2
        elif a.startswith("--slack="):
            try:
                slack = float(a.split("=", 1)[1])
            except ValueError:
                print("bench_regress: bad --slack", file=sys.stderr)
                return 2
        elif a.startswith("--counter="):
            counter = a.split("=", 1)[1]
            if not counter:
                print("bench_regress: empty --counter name", file=sys.stderr)
                return 2
        elif a.startswith("--"):
            print(f"bench_regress: unknown flag {a}", file=sys.stderr)
            return 2
    if len(args) != 2:
        print(__doc__, file=sys.stderr)
        return 2
    current_path, baseline_path = args

    if counter is not None:
        return gate_counter(current_path, baseline_path, counter, slack)

    try:
        cur_sha, cur = load_timings(current_path)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"bench_regress: cannot read current report: {e}", file=sys.stderr)
        return 2
    try:
        base_sha, base = load_timings(baseline_path)
    except FileNotFoundError:
        print(
            f"bench_regress: no baseline at {baseline_path} — skipping "
            "(commit a green run's report there to arm the gate)"
        )
        return 0
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"bench_regress: cannot read baseline: {e}", file=sys.stderr)
        return 2

    matched = sorted(set(cur) & set(base))
    only_cur = sorted(set(cur) - set(base))
    only_base = sorted(set(base) - set(cur))

    print(f"bench_regress: current {cur_sha[:12]} vs baseline {base_sha[:12]}, "
          f"{len(matched)} matched labels, threshold +{threshold:.0%}")

    regressions = []
    for label in matched:
        b, c = base[label], cur[label]
        ratio = c / b if b > 0 else float("inf")
        mark = ""
        if ratio > 1.0 + threshold:
            regressions.append(label)
            mark = "  <-- REGRESSION"
        print(f"  {label}: {fmt_ns(b)} -> {fmt_ns(c)}  (x{ratio:.2f}){mark}")
    for label in only_cur:
        print(f"  (new, unguarded)   {label}: {fmt_ns(cur[label])}")
    for label in only_base:
        print(f"  (baseline-only)    {label}")

    if regressions:
        print(
            f"bench_regress: FAIL — {len(regressions)} label(s) regressed "
            f"more than {threshold:.0%}: {', '.join(regressions)}",
            file=sys.stderr,
        )
        return 1
    print("bench_regress: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
