"""Deterministic synthetic data, bit-compatible with rust/src/data/.

Both languages implement the identical xorshift64* generator and the
identical f32 arithmetic (sequential 12-uniform Irwin–Hall sums for
normals), so the rust coordinator and the python mirror trainer consume the
*same bytes* — that is what makes golden.json a meaningful cross-language
test of the update rules rather than a statistical one.

Two workloads (DESIGN.md substitution #2):

- ``lm``    — noisy affine Markov chain over a vocab: next = (5·cur + 1 +
              rng % (V/4)) mod V.  A bigram model can reduce loss from
              log(V) to ~log(V/4), so the loss curve shows real learning.
- ``class`` — C Gaussian class prototypes + isotropic noise; prototypes are
              drawn once from the seed, so train/test splits share them.
"""

from __future__ import annotations

import numpy as np

MASK64 = (1 << 64) - 1
PHI64 = 0x9E3779B97F4A7C15


class XorShift64Star:
    """xorshift64* — matches rust/src/util/rng.rs exactly."""

    def __init__(self, seed: int):
        self.s = (seed & MASK64) or PHI64

    def next_u64(self) -> int:
        s = self.s
        s ^= s >> 12
        s = (s ^ (s << 25)) & MASK64
        s ^= s >> 27
        self.s = s
        return (s * 0x2545F4914F6CDD1D) & MASK64

    def next_below(self, n: int) -> int:
        return self.next_u64() % n

    def uniform(self) -> np.float32:
        """f32 in [0, 1) with exactly 24 bits of mantissa."""
        return np.float32((self.next_u64() >> 40) * (1.0 / (1 << 24)))

    def normal(self) -> np.float32:
        """Irwin–Hall(12) − 6, summed sequentially in f32."""
        acc = np.float32(0.0)
        for _ in range(12):
            acc = np.float32(acc + self.uniform())
        return np.float32(acc - np.float32(6.0))


def splitmix64(x: int) -> int:
    """Finalizer used to derive per-(step, microbatch) seeds."""
    x = (x + PHI64) & MASK64
    z = x
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
    return z ^ (z >> 31)


def microbatch_seed(base: int, step: int, mb: int) -> int:
    return splitmix64((base ^ (step * 1000003 + mb + 1)) & MASK64)


# ------------------------------------------------------------------- lm ----
def lm_microbatch(base_seed: int, step: int, mb: int, batch: int, seq: int, vocab: int):
    """Returns (inputs [B,S] int32, targets [B,S] int32)."""
    rng = XorShift64Star(microbatch_seed(base_seed, step, mb))
    noise = max(vocab // 4, 1)
    toks = np.empty((batch, seq + 1), dtype=np.int32)
    for b in range(batch):
        cur = rng.next_below(vocab)
        toks[b, 0] = cur
        for s in range(seq):
            cur = (5 * cur + 1 + rng.next_below(noise)) % vocab
            toks[b, s + 1] = cur
    return toks[:, :-1], toks[:, 1:]


# ---------------------------------------------------------------- class ----
def class_prototypes(base_seed: int, classes: int, dim: int) -> np.ndarray:
    """[C, dim] f32 prototypes; derived from base_seed ^ 0xC1A55."""
    rng = XorShift64Star(splitmix64(base_seed ^ 0xC1A55))
    out = np.empty((classes, dim), dtype=np.float32)
    for c in range(classes):
        for d in range(dim):
            out[c, d] = rng.normal()
    return out


def class_microbatch(
    base_seed: int,
    step: int,
    mb: int,
    batch: int,
    protos: np.ndarray,
    noise: float = 0.3,
):
    """Returns (x [B, dim] f32, labels [B] int32)."""
    classes, dim = protos.shape
    rng = XorShift64Star(microbatch_seed(base_seed, step, mb))
    x = np.empty((batch, dim), dtype=np.float32)
    y = np.empty((batch,), dtype=np.int32)
    nf = np.float32(noise)
    for b in range(batch):
        c = rng.next_below(classes)
        y[b] = c
        for d in range(dim):
            x[b, d] = np.float32(protos[c, d] + nf * rng.normal())
    return x, y
