"""Row-blocked layernorm Pallas kernel.

Each grid cell normalizes a [block_rows, D] tile entirely in VMEM: one HBM
read and one write per element (mean/var/normalize fused), versus three
passes for the naive composition.  D stays un-tiled — a transformer row
(D <= 4096 f32 = 16 KiB) always fits a VMEM tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ln_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...]
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    o_ref[...] = (x - mean) * inv * g_ref[...] + b_ref[...]


def layernorm(x, gamma, beta, eps: float = 1e-5, *, block_rows: int = 128):
    """Row-wise layernorm. x: [M, D], gamma/beta: [D]."""
    m, d = x.shape
    br = min(m, block_rows)
    while m % br != 0:
        br -= 1
    grid = (m // br,)
    return pl.pallas_call(
        functools.partial(_ln_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        interpret=True,
    )(x, gamma, beta)
