"""Differentiable wrappers around the Pallas kernels.

``pallas_call`` has no automatic VJP, so each kernel gets a ``custom_vjp``:

- ``linear``      — fwd: Pallas tiled GEMM; bwd: *also* Pallas GEMMs
                    (gx = gz·wᵀ, gw = xᵀ·gz) since those carry the FLOPs.
                    The pre-activation z is recomputed in the bwd (stage-level
                    remat — DESIGN.md §Perf-L2) instead of being stashed.
- ``layernorm``   — fwd: Pallas; bwd: closed-form jnp (memory-bound
                    elementwise, XLA fuses it).
- ``attention``   — fwd: Pallas fused head kernel; bwd: vjp of the jnp
                    reference (recompute).  A dedicated bwd kernel is the
                    flash-bwd extension noted in DESIGN.md §Perf-L1.

The result: every staged-model fwd AND bwd HLO contains the Pallas-lowered
ops on its hot path, while remaining fully differentiable for jax.vjp in
model.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import attention as attn_k
from . import layernorm as ln_k
from . import matmul as mm_k
from . import ref


# ----------------------------------------------------------------- linear --
@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def linear(x, w, b, activation):
    return mm_k.linear(x, w, b, activation)


def _linear_fwd(x, w, b, activation):
    return mm_k.linear(x, w, b, activation), (x, w, b)


def _act_grad(z, activation):
    """d act(z) / dz, elementwise."""
    if activation is None or activation == "none":
        return jnp.ones_like(z)
    if activation == "relu":
        return (z > 0).astype(z.dtype)
    if activation == "gelu":
        c = jnp.sqrt(2.0 / jnp.pi).astype(z.dtype)
        inner = c * (z + 0.044715 * z**3)
        t = jnp.tanh(inner)
        return 0.5 * (1.0 + t) + 0.5 * z * (1.0 - t**2) * c * (
            1.0 + 3 * 0.044715 * z**2
        )
    raise ValueError(activation)


def _linear_bwd(activation, res, gy):
    x, w, b = res
    if activation is None or activation == "none":
        gz = gy
    else:
        z = mm_k.linear(x, w, b, None)  # remat the pre-activation
        gz = gy * _act_grad(z, activation)
    gx = mm_k.linear(gz, w.T, None, None)
    gw = mm_k.linear(x.T, gz, None, None)
    gb = None if b is None else jnp.sum(gz, axis=0)
    return gx, gw, gb


linear.defvjp(_linear_fwd, _linear_bwd)


# -------------------------------------------------------------- layernorm --
@jax.custom_vjp
def layernorm(x, gamma, beta):
    return ln_k.layernorm(x, gamma, beta)


def _ln_fwd(x, gamma, beta):
    return ln_k.layernorm(x, gamma, beta), (x, gamma)


def _ln_bwd(res, gy):
    x, gamma = res
    eps = 1e-5
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    xhat = (x - mean) * inv
    gxhat = gy * gamma
    gx = inv * (
        gxhat
        - jnp.mean(gxhat, axis=-1, keepdims=True)
        - xhat * jnp.mean(gxhat * xhat, axis=-1, keepdims=True)
    )
    ggamma = jnp.sum(gy * xhat, axis=0)
    gbeta = jnp.sum(gy, axis=0)
    return gx, ggamma, gbeta


layernorm.defvjp(_ln_fwd, _ln_bwd)


# -------------------------------------------------------------- attention --
@jax.custom_vjp
def attention(q, k, v):
    return attn_k.attention(q, k, v)


def _attn_fwd(q, k, v):
    return attn_k.attention(q, k, v), (q, k, v)


def _attn_bwd(res, gy):
    q, k, v = res
    _, vjp = jax.vjp(ref.attention_ref, q, k, v)
    return vjp(gy)


attention.defvjp(_attn_fwd, _attn_bwd)
