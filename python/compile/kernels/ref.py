"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references: `pytest python/tests/test_kernels.py`
sweeps shapes/dtypes (hypothesis) and asserts the Pallas kernels (run under
``interpret=True``) match these within tolerance.  The L2 model also uses
these implementations under ``use_pallas=False`` so model-level tests can
cross-check the two paths.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def linear_ref(x, w, b=None, activation: str | None = None):
    """y = act(x @ w + b). x: [M, K], w: [K, N], b: [N] or None."""
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    if b is not None:
        y = y + b
    return apply_activation(y, activation)


def apply_activation(y, activation: str | None):
    if activation is None or activation == "none":
        return y
    if activation == "relu":
        return jnp.maximum(y, 0.0)
    if activation == "gelu":
        # tanh approximation, matches the kernel exactly.
        c = jnp.sqrt(2.0 / jnp.pi).astype(y.dtype)
        return 0.5 * y * (1.0 + jnp.tanh(c * (y + 0.044715 * y**3)))
    raise ValueError(f"unknown activation: {activation}")


def layernorm_ref(x, gamma, beta, eps: float = 1e-5):
    """Row-wise layernorm. x: [M, D], gamma/beta: [D]."""
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    return (x - mean) * inv * gamma + beta


def attention_ref(q, k, v):
    """Scaled dot-product attention with causal mask.

    q, k, v: [H, S, Dh] (single micro-batch element, H heads folded in the
    leading dim).  Returns [H, S, Dh].
    """
    s = q.shape[-2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], dtype=q.dtype))
    scores = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(mask[None, :, :], scores, jnp.finfo(scores.dtype).min)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", probs, v)


def sgd_momentum_ref(p, m, g, lr, mu: float = 0.9):
    """Fused SGD with momentum (PyTorch convention, no dampening).

    m' = mu * m + g ; p' = p - lr * m'.  lr is a scalar array of shape (1,).
    """
    m_new = mu * m + g
    p_new = p - lr.reshape(()) * m_new
    return p_new, m_new
