"""Fused causal attention Pallas kernel.

One grid cell = one attention head: QKᵀ → numerically-stable causal softmax
→ ·V computed entirely in VMEM, so the S×S score matrix never touches HBM —
the flash-attention insight restated for TPU scratchpad memory (DESIGN.md
§Hardware-adaptation).  For the sequence lengths this repo trains
(S ≤ 512), a whole head's scores (512² f32 = 1 MiB) fit VMEM comfortably,
so no K/V streaming loop is needed; the streaming variant is noted in
DESIGN.md §Perf-L1 as the S > 2048 extension.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref):
    q = q_ref[0]  # [S, Dh]
    k = k_ref[0]
    v = v_ref[0]
    s, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=q.dtype))
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    # Causal mask via iota comparison (2D iota: TPU-friendly).
    rows = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    scores = jnp.where(cols <= rows, scores, jnp.finfo(scores.dtype).min)
    mx = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - mx)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p / denom, v, preferred_element_type=jnp.float32)


def attention(q, k, v):
    """Causal attention over [H, S, Dh] (heads in the grid axis)."""
    h, s, dh = q.shape
    spec = pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0))
    return pl.pallas_call(
        _attn_kernel,
        grid=(h,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((h, s, dh), jnp.float32),
        interpret=True,
    )(q, k, v)
