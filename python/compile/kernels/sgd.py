"""Fused SGD-with-momentum Pallas kernel.

The optimizer touch is the model-state hot path that CDP's point-to-point
parameter hand-off relies on (paper §4.4): each tensor must be read and
written exactly once per training step.  The fusion m' = mu*m + g;
p' = p - lr*m' does one read of (p, m, g) and one write of (p', m') per
element, versus 3 reads + 2 writes for the unfused composition.

Tensors are processed as flat [L]-vectors blocked into VMEM-sized chunks;
`lr` rides along as a (1,)-shaped input broadcast to every grid cell (it
changes per step — LR schedules — so it cannot be baked into the HLO).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 16 * 1024  # 64 KiB f32 per operand tile


def _sgd_kernel(p_ref, m_ref, g_ref, lr_ref, po_ref, mo_ref, *, mu: float):
    m_new = mu * m_ref[...] + g_ref[...]
    po_ref[...] = p_ref[...] - lr_ref[0] * m_new
    mo_ref[...] = m_new


def sgd_momentum_flat(p, m, g, lr, mu: float = 0.9, *, block: int = DEFAULT_BLOCK):
    """Fused update on flat f32 vectors. p, m, g: [L]; lr: [1]."""
    (l,) = p.shape
    blk = min(l, block)
    while l % blk != 0:
        blk -= 1
    grid = (l // blk,)
    vec = pl.BlockSpec((blk,), lambda i: (i,))
    scalar = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        functools.partial(_sgd_kernel, mu=mu),
        grid=grid,
        in_specs=[vec, vec, vec, scalar],
        out_specs=[vec, vec],
        out_shape=[
            jax.ShapeDtypeStruct((l,), jnp.float32),
            jax.ShapeDtypeStruct((l,), jnp.float32),
        ],
        interpret=True,
    )(p, m, g, lr)


def sgd_momentum(p, m, g, lr, mu: float = 0.9):
    """Shape-preserving wrapper: flattens, updates, reshapes."""
    shape = p.shape
    p_new, m_new = sgd_momentum_flat(
        p.reshape(-1), m.reshape(-1), g.reshape(-1), lr, mu
    )
    return p_new.reshape(shape), m_new.reshape(shape)
