"""Tiled matmul + bias + activation Pallas kernel (the GEMM hot path).

TPU mapping of the paper's cuDNN GEMM substrate (DESIGN.md
§Hardware-adaptation): the (M, N, K) iteration space is tiled into
VMEM-resident blocks via ``BlockSpec``; the output block persists across the
K grid axis and accumulates partial products — the MXU systolic schedule.
Bias add + activation are fused into the final K step so the output tile is
written to HBM exactly once.

Run with ``interpret=True`` everywhere in this repo: the CPU PJRT client
cannot execute Mosaic custom-calls.  Block-shape choice is therefore a
*structural* optimization (VMEM footprint / MXU alignment), quantified in
DESIGN.md §Perf-L1.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default MXU-aligned tile. f32 VMEM budget for (bm,bk)+(bk,bn)+(bm,bn)
# at 128³ is 3 * 64 KiB = 192 KiB, far under the ~16 MiB/core budget; the
# default leaves room for double-buffering (see DESIGN.md §Perf-L1).
DEFAULT_BLOCK = 128


def _pick_block(dim: int, preferred: int) -> int:
    """Largest divisor of `dim` that is <= preferred (power-of-two dims)."""
    b = min(dim, preferred)
    while dim % b != 0:
        b -= 1
    return b


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, *, activation, nk: int):
    """Grid = (M/bm, N/bn, K/bk); K is the innermost (fastest) axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _finish():
        acc = o_ref[...]
        if b_ref is not None:
            acc = acc + b_ref[...]
        o_ref[...] = ref.apply_activation(acc, activation)


def linear(
    x,
    w,
    b=None,
    activation: str | None = None,
    *,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
):
    """act(x @ w + b) with x: [M, K], w: [K, N], optional b: [N]."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {x.shape} @ {w.shape}"
    bm = _pick_block(m, block_m or DEFAULT_BLOCK)
    bn = _pick_block(n, block_n or DEFAULT_BLOCK)
    bk = _pick_block(k, block_k or DEFAULT_BLOCK)
    nk = k // bk
    grid = (m // bm, n // bn, nk)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    args = [x, w]
    if b is not None:
        in_specs.append(pl.BlockSpec((bn,), lambda i, j, kk: (j,)))
        args.append(b)
        kernel = functools.partial(_matmul_kernel, activation=activation, nk=nk)
    else:
        kernel = functools.partial(
            lambda x_ref, w_ref, o_ref, **kw: _matmul_kernel(
                x_ref, w_ref, None, o_ref, **kw
            ),
            activation=activation,
            nk=nk,
        )

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(*args)


def vmem_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4) -> int:
    """Resident VMEM for one grid cell (x, w and o tiles)."""
    return dtype_bytes * (bm * bk + bk * bn + bm * bn)


def mxu_alignment(bm: int, bn: int, bk: int, lane: int = 128) -> float:
    """Fraction of the tile that maps onto whole MXU lanes (1.0 = perfect)."""

    def frac(d):
        return (d // lane) * lane / d if d >= lane else d / lane

    return min(frac(bm), frac(bn), frac(bk))
