"""Bundle configurations: one entry per AOT artifact set `make artifacts` builds.

A bundle = staged model + data distribution + optimizer hyperparams +
golden-trace length.  ``tiny`` / ``mlp`` / ``convnet`` are small enough to
carry cross-language golden traces; ``lm_small`` is the end-to-end LM
driver's default; ``lm_gpt2s`` is the ~100M-class config (GPT-2-small
shape), built on demand (`python -m compile.aot --bundles lm_gpt2s`).
"""

from __future__ import annotations

from .model import ConvNetConfig, MlpConfig, TransformerConfig, build_model


def bundle_config(name: str) -> dict:
    if name == "tiny":
        cfg = TransformerConfig(
            vocab=64, d_model=32, n_heads=2, n_layers=4, d_ff=64, seq=16,
            microbatch=4, n_stages=4,
        )
        return dict(
            name=name, family="transformer", cfg=cfg, seed=1234,
            lr=0.05, momentum=0.9, golden_steps=8,
            data=dict(kind="lm", vocab=cfg.vocab, seq=cfg.seq,
                      batch=cfg.microbatch, seed=42),
        )
    if name == "mlp":
        cfg = MlpConfig(classes=10, input_dim=64, hidden=128,
                        layers_per_stage=2, microbatch=8, n_stages=4)
        return dict(
            name=name, family="mlp", cfg=cfg, seed=7, lr=0.01, momentum=0.9,
            golden_steps=8,
            data=dict(kind="class", classes=10, input_dim=64, noise=0.3,
                      batch=cfg.microbatch, seed=99),
        )
    if name == "convnet":
        cfg = ConvNetConfig(classes=10, image_hw=32, in_channels=3,
                            base_channels=16, blocks_per_stage=1,
                            microbatch=8, n_stages=4)
        return dict(
            name=name, family="convnet", cfg=cfg, seed=21, lr=0.05,
            momentum=0.9, golden_steps=4,
            data=dict(kind="class", classes=10, input_dim=cfg.input_dim,
                      noise=0.3, batch=cfg.microbatch, seed=77),
        )
    if name == "lm_small":
        cfg = TransformerConfig(
            vocab=512, d_model=256, n_heads=8, n_layers=8, d_ff=1024,
            seq=64, microbatch=4, n_stages=4,
        )
        return dict(
            name=name, family="transformer", cfg=cfg, seed=3407,
            lr=0.05, momentum=0.9, golden_steps=0,
            data=dict(kind="lm", vocab=cfg.vocab, seq=cfg.seq,
                      batch=cfg.microbatch, seed=2026),
        )
    if name == "lm_gpt2s":
        # GPT-2-small class: 12 layers, d=768, ~110M params (V=16384).
        cfg = TransformerConfig(
            vocab=16384, d_model=768, n_heads=12, n_layers=12, d_ff=3072,
            seq=256, microbatch=1, n_stages=4,
        )
        return dict(
            name=name, family="transformer", cfg=cfg, seed=3407,
            lr=0.01, momentum=0.9, golden_steps=0,
            data=dict(kind="lm", vocab=cfg.vocab, seq=cfg.seq,
                      batch=cfg.microbatch, seed=2026),
        )
    raise ValueError(f"unknown bundle: {name}")


def make_bundle_model(bc: dict):
    return build_model(bc["family"], bc["cfg"])
