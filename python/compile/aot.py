"""AOT compiler: staged models → HLO-text artifact bundles for the rust runtime.

For each bundle (configs.py) this emits into ``artifacts/<bundle>/``:

- ``stage{j}_fwd.hlo.txt``      j < N-1
- ``stage{j}_fwdbwd.hlo.txt``   all j (arity differs; see manifest)
- ``stage{N-1}_fwdloss.hlo.txt`` and, for classifiers, ``..._predict.hlo.txt``
- ``stage{j}_sgd.hlo.txt``      fused SGD-momentum for that stage's tensors
- ``params.bin``                f32 LE init params, manifest order
- ``manifest.json``             shapes/dtypes/arity/data/hyperparams
- ``golden.json``               per-step losses of DP / CDP-v1 / CDP-v2 from
                                the python mirror trainer (cross-language test)

Interchange is **HLO text**, not serialized protos: jax ≥ 0.5 emits 64-bit
instruction ids that xla_extension 0.5.1 (the version the rust `xla` crate
binds) rejects; the text parser reassigns ids (see /opt/xla-example).

Python runs ONCE here; nothing in this package is imported at runtime.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs, mirror
from .model import make_stage_fns

DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype="f32"):
    return jax.ShapeDtypeStruct(tuple(shape), DTYPES[dtype])


def lower_to_file(fn, arg_specs, path: str) -> int:
    # keep_unused=True: the rust caller passes every manifest argument;
    # without it jit DCEs dead inputs (e.g. a final bias whose effect is
    # only visible in the discarded fwd output of a fwdbwd artifact) and
    # the arities disagree at execute time.
    lowered = jax.jit(fn, keep_unused=True).lower(*arg_specs)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def build_bundle(name: str, out_root: str, skip_golden: bool = False) -> None:
    t0 = time.time()
    bc = configs.bundle_config(name)
    model = configs.make_bundle_model(bc)
    out_dir = os.path.join(out_root, name)
    os.makedirs(out_dir, exist_ok=True)
    n = model.n_stages
    is_class = bc["data"]["kind"] == "class"

    params0 = model.init_params(bc["seed"])

    stages_meta = []
    for j in range(n):
        specs_j = model.stage_specs[j]
        pspecs = [spec(s.shape) for s in specs_j]
        in_spec = model.input_spec(j)
        x_spec = spec(in_spec.shape, in_spec.dtype)
        fns = make_stage_fns(model, j)
        arts = {}
        last = j == n - 1
        if not last:
            out_spec = model.output_spec(j)
            gy_spec = spec(out_spec.shape, out_spec.dtype)
            arts["fwd"] = f"stage{j}_fwd.hlo.txt"
            lower_to_file(fns["fwd"], pspecs + [x_spec], os.path.join(out_dir, arts["fwd"]))
            arts["fwdbwd"] = f"stage{j}_fwdbwd.hlo.txt"
            lower_to_file(
                fns["fwdbwd"], pspecs + [x_spec, gy_spec],
                os.path.join(out_dir, arts["fwdbwd"]),
            )
        else:
            t_spec_ = model.target_spec()
            tgt_spec = spec(t_spec_.shape, t_spec_.dtype)
            arts["fwd_loss"] = f"stage{j}_fwdloss.hlo.txt"
            lower_to_file(
                fns["fwd_loss"], pspecs + [x_spec, tgt_spec],
                os.path.join(out_dir, arts["fwd_loss"]),
            )
            arts["fwdbwd"] = f"stage{j}_fwdbwd.hlo.txt"
            lower_to_file(
                fns["fwdbwd"], pspecs + [x_spec, tgt_spec],
                os.path.join(out_dir, arts["fwdbwd"]),
            )
            if is_class:
                arts["predict"] = f"stage{j}_predict.hlo.txt"
                lower_to_file(
                    fns["predict"], pspecs + [x_spec],
                    os.path.join(out_dir, arts["predict"]),
                )
        arts["sgd"] = f"stage{j}_sgd.hlo.txt"
        lr_spec = spec((1,))
        lower_to_file(
            fns["sgd"], pspecs + pspecs + pspecs + [lr_spec],
            os.path.join(out_dir, arts["sgd"]),
        )

        out_sp = model.output_spec(j) if not last else None
        stages_meta.append(
            dict(
                index=j,
                params=[dict(name=s.name, shape=list(s.shape)) for s in specs_j],
                n_params=len(specs_j),
                param_elems=int(sum(s.elems for s in specs_j)),
                input=dict(shape=list(in_spec.shape), dtype=in_spec.dtype),
                output=(dict(shape=list(out_sp.shape), dtype=out_sp.dtype)
                        if out_sp else None),
                act_bytes=int(model.stage_act_bytes(j)),
                flops=int(model.stage_flops(j)),
                artifacts=arts,
            )
        )

    # params.bin: stage-major, manifest order, f32 LE.
    with open(os.path.join(out_dir, "params.bin"), "wb") as f:
        for st in params0:
            for a in st:
                f.write(np.ascontiguousarray(a, dtype="<f4").tobytes())

    golden = None
    if bc["golden_steps"] > 0 and not skip_golden:
        tr = mirror.MirrorTrainer(model, bc["data"], bc["lr"], bc["momentum"])
        golden = {"steps": bc["golden_steps"], "rules": {}}
        for rule in mirror.RULES:
            losses, _ = tr.train(params0, rule, bc["golden_steps"])
            if not all(np.isfinite(losses)):
                raise RuntimeError(
                    f"bundle {name} rule {rule} diverged: {losses} — "
                    "golden traces must be finite"
                )
            golden["rules"][rule] = losses
        with open(os.path.join(out_dir, "golden.json"), "w") as f:
            json.dump(golden, f, indent=1)

    tspec = model.target_spec()
    manifest = dict(
        name=name,
        family=bc["family"],
        n_stages=n,
        n_microbatches=n,  # paper: N stages == N micro-batches
        lr=bc["lr"],
        momentum=bc["momentum"],
        data=bc["data"],
        target=dict(shape=list(tspec.shape), dtype=tspec.dtype),
        stages=stages_meta,
        params_bin="params.bin",
        golden="golden.json" if golden else None,
        golden_steps=bc["golden_steps"] if golden else 0,
        total_param_elems=int(sum(m["param_elems"] for m in stages_meta)),
    )
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] bundle {name}: {n} stages, "
          f"{manifest['total_param_elems']:,} params, {time.time()-t0:.1f}s")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-root", default="../artifacts")
    ap.add_argument("--bundles", nargs="+", default=["tiny", "mlp"])
    ap.add_argument("--skip-golden", action="store_true")
    args = ap.parse_args()
    for b in args.bundles:
        build_bundle(b, args.out_root, args.skip_golden)


if __name__ == "__main__":
    main()
