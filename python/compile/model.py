"""L2: staged JAX models whose per-stage fwd / fwd+bwd lower to HLO.

The paper's execution unit is a *stage*: the model is partitioned into N
stages (paper: "split into 4 stages with similar FLOPs"), and one time step
executes one stage-granularity forward or backward on one micro-batch.  We
therefore AOT-export per-stage functions, never a whole-model function:

  stage 0      : fwd(params, tokens|x) -> y          bwd(params, x, gy) -> gparams
  stage j mid  : fwd(params, x) -> y                 bwd(params, x, gy) -> (gx, gparams)
  stage N-1    : fwd_loss(params, x, tgt) -> loss    bwd(params, x, tgt) -> (loss, gx, gparams)
                 predict(params, x) -> logits        (classification eval)
  every stage  : sgd(params, moms, grads, lr) -> (params', moms')

The backward recomputes the stage forward from the stage *input* (stage-
granularity rematerialization): the only activation that crosses the
Rust↔HLO boundary between a micro-batch's fwd and bwd of a stage is the
stage input, which is exactly the activation-stash unit the paper's memory
accounting (Fig 4, Tab 1) is phrased in.

Three families share the interface (`StagedModel`):

- ``transformer`` — pre-LN GPT-style LM; Pallas kernels on every hot path.
- ``convnet``     — ResNet-style residual CNN for the CIFAR-10 analog
                    (Table 2).  BatchNorm is replaced by stateless
                    channel-LayerNorm (DESIGN.md substitution #2).
- ``mlp``         — small residual MLP classifier; fast numeric model for
                    benches and tests.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import diff


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: Tuple[int, ...]

    @property
    def elems(self) -> int:
        return int(np.prod(self.shape))


@dataclasses.dataclass(frozen=True)
class IoSpec:
    shape: Tuple[int, ...]
    dtype: str  # "f32" | "i32"


def split_layers(n_layers: int, n_stages: int) -> List[int]:
    """Distribute layers as evenly as possible (earlier stages get extras)."""
    base, rem = divmod(n_layers, n_stages)
    return [base + (1 if i < rem else 0) for i in range(n_stages)]


# =========================================================== transformer ===
@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    vocab: int = 64
    d_model: int = 32
    n_heads: int = 2
    n_layers: int = 4
    d_ff: int = 64
    seq: int = 16
    microbatch: int = 4
    n_stages: int = 4

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


def _layer_specs(prefix: str, d: int, f: int) -> List[ParamSpec]:
    return [
        ParamSpec(f"{prefix}.ln1_g", (d,)),
        ParamSpec(f"{prefix}.ln1_b", (d,)),
        ParamSpec(f"{prefix}.wqkv", (d, 3 * d)),
        ParamSpec(f"{prefix}.bqkv", (3 * d,)),
        ParamSpec(f"{prefix}.wo", (d, d)),
        ParamSpec(f"{prefix}.bo", (d,)),
        ParamSpec(f"{prefix}.ln2_g", (d,)),
        ParamSpec(f"{prefix}.ln2_b", (d,)),
        ParamSpec(f"{prefix}.w1", (d, f)),
        ParamSpec(f"{prefix}.b1", (f,)),
        ParamSpec(f"{prefix}.w2", (f, d)),
        ParamSpec(f"{prefix}.b2", (d,)),
    ]


PARAMS_PER_LAYER = 12


class Transformer:
    """GPT-style causal LM, partitioned into n_stages stages."""

    family = "transformer"

    def __init__(self, cfg: TransformerConfig):
        self.cfg = cfg
        self.n_stages = cfg.n_stages
        counts = split_layers(cfg.n_layers, cfg.n_stages)
        self.layer_counts = counts
        d, f = cfg.d_model, cfg.d_ff
        self.stage_specs: List[List[ParamSpec]] = []
        layer_idx = 0
        for j in range(cfg.n_stages):
            specs: List[ParamSpec] = []
            if j == 0:
                specs.append(ParamSpec("tok_emb", (cfg.vocab, d)))
                specs.append(ParamSpec("pos_emb", (cfg.seq, d)))
            for _ in range(counts[j]):
                specs.extend(_layer_specs(f"layer{layer_idx}", d, f))
                layer_idx += 1
            if j == cfg.n_stages - 1:
                specs.append(ParamSpec("lnf_g", (d,)))
                specs.append(ParamSpec("lnf_b", (d,)))
                specs.append(ParamSpec("w_head", (d, cfg.vocab)))
                specs.append(ParamSpec("b_head", (cfg.vocab,)))
            self.stage_specs.append(specs)

    # ---- io specs -----------------------------------------------------
    def input_spec(self, j: int) -> IoSpec:
        c = self.cfg
        if j == 0:
            return IoSpec((c.microbatch, c.seq), "i32")
        return IoSpec((c.microbatch, c.seq, c.d_model), "f32")

    def output_spec(self, j: int) -> IoSpec:
        c = self.cfg
        return IoSpec((c.microbatch, c.seq, c.d_model), "f32")

    def target_spec(self) -> IoSpec:
        c = self.cfg
        return IoSpec((c.microbatch, c.seq), "i32")

    # ---- init ----------------------------------------------------------
    def init_params(self, seed: int) -> List[List[np.ndarray]]:
        rng = np.random.default_rng(seed)
        out: List[List[np.ndarray]] = []
        for specs in self.stage_specs:
            stage = []
            for s in specs:
                leaf = s.name.rsplit(".", 1)[-1]
                if leaf.endswith("_g"):
                    a = np.ones(s.shape, np.float32)
                elif leaf.startswith("b") or leaf.endswith("_b"):
                    a = np.zeros(s.shape, np.float32)
                elif leaf in ("tok_emb", "pos_emb"):
                    a = rng.normal(0.0, 0.02, s.shape).astype(np.float32)
                else:
                    std = 1.0 / math.sqrt(s.shape[0])
                    a = rng.normal(0.0, std, s.shape).astype(np.float32)
                stage.append(a)
            out.append(stage)
        return out

    # ---- compute -------------------------------------------------------
    def _layer(self, p: Sequence[jnp.ndarray], x2: jnp.ndarray) -> jnp.ndarray:
        c = self.cfg
        b, s, d, h = c.microbatch, c.seq, c.d_model, c.n_heads
        dh = c.head_dim
        ln1g, ln1b, wqkv, bqkv, wo, bo, ln2g, ln2b, w1, b1, w2, b2 = p
        hdd = diff.layernorm(x2, ln1g, ln1b)
        qkv = diff.linear(hdd, wqkv, bqkv, None)  # [B*S, 3D]
        qkv = qkv.reshape(b, s, 3, h, dh).transpose(2, 0, 3, 1, 4)
        q, k, v = (t.reshape(b * h, s, dh) for t in (qkv[0], qkv[1], qkv[2]))
        a = diff.attention(q, k, v)
        a = a.reshape(b, h, s, dh).transpose(0, 2, 1, 3).reshape(b * s, d)
        x2 = x2 + diff.linear(a, wo, bo, None)
        h2 = diff.layernorm(x2, ln2g, ln2b)
        m = diff.linear(h2, w1, b1, "gelu")
        x2 = x2 + diff.linear(m, w2, b2, None)
        return x2

    def _stage_layers(self, j: int, params: Sequence[jnp.ndarray], x2, lo: int):
        for li in range(self.layer_counts[j]):
            p = params[lo + li * PARAMS_PER_LAYER : lo + (li + 1) * PARAMS_PER_LAYER]
            x2 = self._layer(p, x2)
        return x2

    def stage_apply(self, j: int, params: Sequence[jnp.ndarray], x):
        """Forward of stage j (j < n_stages-1 plain; j = n_stages-1 via
        loss_apply/predict_apply)."""
        c = self.cfg
        b, s, d = c.microbatch, c.seq, c.d_model
        if j == 0:
            tok_emb, pos_emb = params[0], params[1]
            x3 = tok_emb[x] + pos_emb[None, :, :]
            x2 = x3.reshape(b * s, d)
            x2 = self._stage_layers(0, params, x2, 2)
        else:
            x2 = x.reshape(b * s, d)
            x2 = self._stage_layers(j, params, x2, 0)
        return x2.reshape(b, s, d)

    def _final_logits(self, params: Sequence[jnp.ndarray], x):
        c = self.cfg
        b, s, d = c.microbatch, c.seq, c.d_model
        x2 = x.reshape(b * s, d)
        x2 = self._stage_layers(self.n_stages - 1, params, x2, 0)
        lnf_g, lnf_b, w_head, b_head = params[-4:]
        hdd = diff.layernorm(x2, lnf_g, lnf_b)
        return diff.linear(hdd, w_head, b_head, None)  # [B*S, V]

    def loss_apply(self, params: Sequence[jnp.ndarray], x, targets):
        logits = self._final_logits(params, x)
        t = targets.reshape(-1)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, t[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - ll)

    def predict_apply(self, params: Sequence[jnp.ndarray], x):
        return self._final_logits(params, x)

    # ---- accounting ------------------------------------------------------
    def stage_act_bytes(self, j: int) -> int:
        """Analytic activation stash of one micro-batch's fwd through stage
        j (floats held awaiting bwd), following the paper's B·Ψ_A unit."""
        c = self.cfg
        tokens = c.microbatch * c.seq
        per_tok = 0
        if j == 0:
            per_tok += 2 * c.d_model  # embedding output + residual
        # per layer: ln in/out, qkv, attn out, wo out, ln2, mlp hidden, out
        per_layer = 4 * c.d_model + 3 * c.d_model + 2 * c.d_model + c.d_ff
        per_tok += self.layer_counts[j] * per_layer
        if j == self.n_stages - 1:
            per_tok += c.d_model + c.vocab
        return 4 * tokens * per_tok

    def stage_flops(self, j: int) -> int:
        c = self.cfg
        tokens = c.microbatch * c.seq
        d, f = c.d_model, c.d_ff
        per_layer = 2 * tokens * (3 * d * d + d * d + 2 * d * f) + 4 * tokens * c.seq * d
        fl = self.layer_counts[j] * per_layer
        if j == 0:
            fl += 2 * tokens * d
        if j == self.n_stages - 1:
            fl += 2 * tokens * d * c.vocab
        return fl


# =============================================================== convnet ===
@dataclasses.dataclass(frozen=True)
class ConvNetConfig:
    classes: int = 10
    image_hw: int = 32
    in_channels: int = 3
    base_channels: int = 16
    blocks_per_stage: int = 1
    microbatch: int = 8
    n_stages: int = 4

    @property
    def input_dim(self) -> int:
        return self.image_hw * self.image_hw * self.in_channels


def _conv(x, w, stride: int = 1):
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


class ConvNet:
    """Residual CNN (ResNet-style; channel-LN instead of BN)."""

    family = "convnet"

    def __init__(self, cfg: ConvNetConfig):
        self.cfg = cfg
        self.n_stages = cfg.n_stages
        # Stage s has channels base * 2^min(s, 2) and halves HW from stage 1.
        self.stage_channels = [
            cfg.base_channels * (2 ** min(s, 2)) for s in range(cfg.n_stages)
        ]
        self.stage_hw = [
            max(cfg.image_hw // (2 ** min(s, 2)), 4) for s in range(cfg.n_stages)
        ]
        self.stage_specs: List[List[ParamSpec]] = []
        for j in range(cfg.n_stages):
            specs: List[ParamSpec] = []
            cj = self.stage_channels[j]
            if j == 0:
                specs.append(ParamSpec("stem_w", (3, 3, cfg.in_channels, cj)))
            else:
                cprev = self.stage_channels[j - 1]
                specs.append(ParamSpec(f"down{j}_w", (3, 3, cprev, cj)))
            for b in range(cfg.blocks_per_stage):
                specs.extend(
                    [
                        ParamSpec(f"s{j}b{b}.ln1_g", (cj,)),
                        ParamSpec(f"s{j}b{b}.ln1_b", (cj,)),
                        ParamSpec(f"s{j}b{b}.conv1_w", (3, 3, cj, cj)),
                        ParamSpec(f"s{j}b{b}.ln2_g", (cj,)),
                        ParamSpec(f"s{j}b{b}.ln2_b", (cj,)),
                        ParamSpec(f"s{j}b{b}.conv2_w", (3, 3, cj, cj)),
                    ]
                )
            if j == cfg.n_stages - 1:
                specs.append(ParamSpec("fc_w", (cj, cfg.classes)))
                specs.append(ParamSpec("fc_b", (cfg.classes,)))
            self.stage_specs.append(specs)

    def input_spec(self, j: int) -> IoSpec:
        c = self.cfg
        if j == 0:
            return IoSpec((c.microbatch, c.input_dim), "f32")
        hw = self.stage_hw[j - 1]
        return IoSpec((c.microbatch, hw, hw, self.stage_channels[j - 1]), "f32")

    def output_spec(self, j: int) -> IoSpec:
        c = self.cfg
        hw = self.stage_hw[j]
        return IoSpec((c.microbatch, hw, hw, self.stage_channels[j]), "f32")

    def target_spec(self) -> IoSpec:
        return IoSpec((self.cfg.microbatch,), "i32")

    def init_params(self, seed: int) -> List[List[np.ndarray]]:
        rng = np.random.default_rng(seed)
        out = []
        for specs in self.stage_specs:
            stage = []
            for s in specs:
                leaf = s.name.rsplit(".", 1)[-1]
                if leaf.endswith("_g"):
                    a = np.ones(s.shape, np.float32)
                elif leaf.endswith("_b") or leaf == "fc_b":
                    a = np.zeros(s.shape, np.float32)
                else:
                    fan_in = int(np.prod(s.shape[:-1]))
                    a = rng.normal(0.0, math.sqrt(2.0 / fan_in), s.shape).astype(
                        np.float32
                    )
                stage.append(a)
            out.append(stage)
        return out

    def _chan_ln(self, x, g, b):
        n, h, w, c = x.shape
        return diff.layernorm(x.reshape(n * h * w, c), g, b).reshape(n, h, w, c)

    def _block(self, p, x):
        ln1g, ln1b, w1, ln2g, ln2b, w2 = p
        h = jnp.maximum(_conv(self._chan_ln(x, ln1g, ln1b), w1), 0.0)
        h = _conv(self._chan_ln(h, ln2g, ln2b), w2)
        return x + h

    def _stage_body(self, j: int, params, x):
        cfg = self.cfg
        if j == 0:
            x = x.reshape(cfg.microbatch, cfg.image_hw, cfg.image_hw, cfg.in_channels)
            x = _conv(x, params[0], 1)
        else:
            stride = 2 if self.stage_hw[j] < self.stage_hw[j - 1] else 1
            x = _conv(x, params[0], stride)
        for b in range(cfg.blocks_per_stage):
            x = self._block(params[1 + 6 * b : 1 + 6 * (b + 1)], x)
        return x

    def stage_apply(self, j: int, params, x):
        return self._stage_body(j, params, x)

    def _final_logits(self, params, x):
        x = self._stage_body(self.n_stages - 1, params, x)
        pooled = jnp.mean(x, axis=(1, 2))  # [B, C]
        fc_w, fc_b = params[-2:]
        return diff.linear(pooled, fc_w, fc_b, None)

    def loss_apply(self, params, x, targets):
        logits = self._final_logits(params, x)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, targets[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - ll)

    def predict_apply(self, params, x):
        return self._final_logits(params, x)

    def stage_act_bytes(self, j: int) -> int:
        c = self.cfg
        hw = self.stage_hw[j]
        elems = c.microbatch * hw * hw * self.stage_channels[j]
        per_block = 6  # ln1, conv1, relu, ln2, conv2, residual
        n = 1 + per_block * c.blocks_per_stage
        return 4 * elems * n

    def stage_flops(self, j: int) -> int:
        c = self.cfg
        hw = self.stage_hw[j]
        ch = self.stage_channels[j]
        pix = c.microbatch * hw * hw
        per_conv = 2 * pix * 9 * ch * ch
        fl = (1 + 2 * c.blocks_per_stage) * per_conv
        if j == self.n_stages - 1:
            fl += 2 * c.microbatch * ch * c.classes
        return fl


# =================================================================== mlp ===
@dataclasses.dataclass(frozen=True)
class MlpConfig:
    classes: int = 10
    input_dim: int = 64
    hidden: int = 128
    layers_per_stage: int = 2
    microbatch: int = 8
    n_stages: int = 4


class Mlp:
    """Residual MLP classifier (fast numeric model for benches/tests)."""

    family = "mlp"

    def __init__(self, cfg: MlpConfig):
        self.cfg = cfg
        self.n_stages = cfg.n_stages
        self.stage_specs = []
        for j in range(cfg.n_stages):
            specs = []
            if j == 0:
                specs.append(ParamSpec("in_w", (cfg.input_dim, cfg.hidden)))
                specs.append(ParamSpec("in_b", (cfg.hidden,)))
            for l in range(cfg.layers_per_stage):
                specs.append(ParamSpec(f"s{j}l{l}_w", (cfg.hidden, cfg.hidden)))
                specs.append(ParamSpec(f"s{j}l{l}_b", (cfg.hidden,)))
            if j == cfg.n_stages - 1:
                specs.append(ParamSpec("out_w", (cfg.hidden, cfg.classes)))
                specs.append(ParamSpec("out_b", (cfg.classes,)))
            self.stage_specs.append(specs)

    def input_spec(self, j: int) -> IoSpec:
        c = self.cfg
        if j == 0:
            return IoSpec((c.microbatch, c.input_dim), "f32")
        return IoSpec((c.microbatch, c.hidden), "f32")

    def output_spec(self, j: int) -> IoSpec:
        return IoSpec((self.cfg.microbatch, self.cfg.hidden), "f32")

    def target_spec(self) -> IoSpec:
        return IoSpec((self.cfg.microbatch,), "i32")

    def init_params(self, seed: int) -> List[List[np.ndarray]]:
        rng = np.random.default_rng(seed)
        out = []
        for specs in self.stage_specs:
            stage = []
            for s in specs:
                if s.name.endswith("_b"):
                    stage.append(np.zeros(s.shape, np.float32))
                elif s.name == "out_w":
                    # small classifier head: initial logits near zero so
                    # the initial loss sits at ln(classes)
                    stage.append(rng.normal(0.0, 0.05, s.shape).astype(np.float32))
                else:
                    std = math.sqrt(1.0 / s.shape[0])
                    stage.append(rng.normal(0.0, std, s.shape).astype(np.float32))
            out.append(stage)
        return out

    # Residual branches are scaled so activation variance stays bounded
    # across the n_stages*layers_per_stage residual adds (without this the
    # logits blow up ~2x per layer and SGD diverges).
    RES_SCALE = 0.3

    def stage_apply(self, j: int, params, x):
        c = self.cfg
        i = 0
        if j == 0:
            x = diff.linear(x, params[0], params[1], "relu")
            i = 2
        for _ in range(c.layers_per_stage):
            x = x + self.RES_SCALE * diff.linear(x, params[i], params[i + 1], "relu")
            i += 2
        return x

    def _final_logits(self, params, x):
        x = self.stage_apply(self.n_stages - 1, params[:-2], x)
        return diff.linear(x, params[-2], params[-1], None)

    def loss_apply(self, params, x, targets):
        logits = self._final_logits(params, x)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, targets[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - ll)

    def predict_apply(self, params, x):
        return self._final_logits(params, x)

    def stage_act_bytes(self, j: int) -> int:
        c = self.cfg
        n = 2 * c.layers_per_stage + (2 if j == 0 else 0)
        return 4 * c.microbatch * c.hidden * n

    def stage_flops(self, j: int) -> int:
        c = self.cfg
        fl = 2 * c.microbatch * c.hidden * c.hidden * c.layers_per_stage
        if j == 0:
            fl += 2 * c.microbatch * c.input_dim * c.hidden
        if j == self.n_stages - 1:
            fl += 2 * c.microbatch * c.hidden * c.classes
        return fl


# =============================================================== helpers ===
def make_stage_fns(model, j: int):
    """Returns dict of pure functions for stage j, with flat-args signatures
    suitable for AOT lowering (params unpacked positionally)."""
    n_params = len(model.stage_specs[j])
    last = j == model.n_stages - 1

    def pack(args):
        return tuple(args[:n_params]), args[n_params:]

    fns = {}
    if not last:

        def fwd(*args):
            params, rest = pack(args)
            return (model.stage_apply(j, params, rest[0]),)

        if j == 0:

            def fwdbwd(*args):
                params, rest = pack(args)
                x, gy = rest
                _, vjp = jax.vjp(lambda p: model.stage_apply(j, p, x), params)
                (gp,) = vjp(gy)
                return tuple(gp)

        else:

            def fwdbwd(*args):
                params, rest = pack(args)
                x, gy = rest
                _, vjp = jax.vjp(
                    lambda p, xx: model.stage_apply(j, p, xx), params, x
                )
                gp, gx = vjp(gy)
                return (gx,) + tuple(gp)

        fns["fwd"] = fwd
        fns["fwdbwd"] = fwdbwd
    else:

        def fwd_loss(*args):
            params, rest = pack(args)
            x, targets = rest
            return (model.loss_apply(params, x, targets),)

        def fwdbwd(*args):
            params, rest = pack(args)
            x, targets = rest
            loss, vjp = jax.vjp(
                lambda p, xx: model.loss_apply(p, xx, targets), params, x
            )
            gp, gx = vjp(jnp.float32(1.0))
            return (loss, gx) + tuple(gp)

        def predict(*args):
            params, rest = pack(args)
            return (model.predict_apply(params, rest[0]),)

        fns["fwd_loss"] = fwd_loss
        fns["fwdbwd"] = fwdbwd
        fns["predict"] = predict

    def sgd(*args):
        from .kernels import sgd as sgd_k

        ps = args[:n_params]
        ms = args[n_params : 2 * n_params]
        gs = args[2 * n_params : 3 * n_params]
        lr = args[3 * n_params]
        new_p, new_m = [], []
        for p, m, g in zip(ps, ms, gs):
            pn, mn = sgd_k.sgd_momentum(p, m, g, lr)
            new_p.append(pn)
            new_m.append(mn)
        return tuple(new_p) + tuple(new_m)

    fns["sgd"] = sgd
    return fns


def build_model(family: str, cfg):
    if family == "transformer":
        return Transformer(cfg)
    if family == "convnet":
        return ConvNet(cfg)
    if family == "mlp":
        return Mlp(cfg)
    raise ValueError(family)
