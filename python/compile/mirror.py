"""Pure-JAX mirror of the rust coordinator's update rules.

This is the *semantic* reference for DP / CDP-v1 / CDP-v2 (paper Sec. 3.2):
it executes the same per-stage functions that aot.py lowers to HLO, applies
the same u_{i,j} parameter-version selection, the same gradient averaging
and the same fused SGD-momentum — on the same deterministic data stream
(datagen).  aot.py records its per-step losses into ``golden.json``; a rust
integration test replays the bundle and must match within fp tolerance.

Update-rule semantics (θ_{-1} := θ_0 bootstrap, micro-batches i = 1..N,
stages j = 1..N):

- DP     : θ̂_{i}^j = θ_t^j                      (all fresh)
- CDP-v1 : θ̂_{i}^j = θ_{t-1}^j                  (all stale; PipeDream-2BW)
- CDP-v2 : θ̂_{i}^j = θ_t^j iff j ≥ N-i+1        (suffix fresh)
"""

from __future__ import annotations

import functools
from typing import Callable, List

import jax
import jax.numpy as jnp
import numpy as np

from . import datagen
from .model import make_stage_fns

RULES = ("dp", "cdp_v1", "cdp_v2")


def use_fresh(rule: str, i: int, j: int, n: int) -> bool:
    """Does micro-batch i (1-based) see the *fresh* θ_t for stage j (1-based)?"""
    if rule == "dp":
        return True
    if rule == "cdp_v1":
        return False
    if rule == "cdp_v2":
        return j >= n - i + 1
    raise ValueError(rule)


class MirrorTrainer:
    def __init__(self, model, data_cfg: dict, lr: float, momentum: float = 0.9):
        self.model = model
        self.data_cfg = data_cfg
        self.lr = lr
        self.momentum = momentum
        self.n = model.n_stages
        self.fns = [make_stage_fns(model, j) for j in range(self.n)]
        self.jit = [
            {k: jax.jit(f) for k, f in stage.items()} for stage in self.fns
        ]
        if data_cfg["kind"] == "class":
            self.protos = datagen.class_prototypes(
                data_cfg["seed"], data_cfg["classes"], data_cfg["input_dim"]
            )

    # ---- data ----------------------------------------------------------
    def microbatch(self, step: int, i: int):
        """Micro-batch i (0-based here) of training step `step`."""
        d = self.data_cfg
        if d["kind"] == "lm":
            return datagen.lm_microbatch(
                d["seed"], step, i, d["batch"], d["seq"], d["vocab"]
            )
        return datagen.class_microbatch(
            d["seed"], step, i, d["batch"], self.protos, d.get("noise", 0.3)
        )

    # ---- one micro-batch fwd+bwd ----------------------------------------
    def run_microbatch(self, params_hat: List[List[jnp.ndarray]], x, targets):
        n = self.n
        acts = [jnp.asarray(x)]
        for j in range(n - 1):
            (y,) = self.jit[j]["fwd"](*params_hat[j], acts[j])
            acts.append(y)
        out = self.jit[n - 1]["fwdbwd"](
            *params_hat[n - 1], acts[n - 1], jnp.asarray(targets)
        )
        loss, gx, gp_last = out[0], out[1], list(out[2:])
        grads = [None] * n
        grads[n - 1] = gp_last
        for j in range(n - 2, 0, -1):
            out = self.jit[j]["fwdbwd"](*params_hat[j], acts[j], gx)
            gx, grads[j] = out[0], list(out[1:])
        if n > 1:  # for n == 1 the loss stage IS stage 0
            grads[0] = list(self.jit[0]["fwdbwd"](*params_hat[0], acts[0], gx))
        return float(loss), grads

    # ---- training --------------------------------------------------------
    def train(self, params0: List[List[np.ndarray]], rule: str, steps: int):
        n = self.n
        theta = [[jnp.asarray(a) for a in st] for st in params0]
        theta_prev = theta
        mom = [[jnp.zeros_like(a) for a in st] for st in theta]
        lr_arr = jnp.asarray([self.lr], dtype=jnp.float32)
        losses = []
        for t in range(steps):
            acc = None
            step_losses = []
            for i in range(1, n + 1):  # micro-batch index, 1-based
                hat = [
                    theta[j] if use_fresh(rule, i, j + 1, n) else theta_prev[j]
                    for j in range(n)
                ]
                x, tgt = self.microbatch(t, i - 1)
                loss, grads = self.run_microbatch(hat, x, tgt)
                step_losses.append(loss)
                if acc is None:
                    acc = grads
                else:
                    acc = [
                        [a + g for a, g in zip(sa, sg)]
                        for sa, sg in zip(acc, grads)
                    ]
            inv_n = jnp.float32(1.0 / n)
            new_theta, new_mom = [], []
            for j in range(n):
                gbar = [a * inv_n for a in acc[j]]
                out = self.jit[j]["sgd"](*theta[j], *mom[j], *gbar, lr_arr)
                k = len(theta[j])
                new_theta.append(list(out[:k]))
                new_mom.append(list(out[k:]))
            theta_prev = theta
            theta = new_theta
            mom = new_mom
            losses.append(float(np.mean(step_losses)))
        return losses, theta

    # ---- eval (classification) -------------------------------------------
    def accuracy(self, theta, n_batches: int = 8, split_base: int = 1_000_000):
        assert self.data_cfg["kind"] == "class"
        correct = total = 0
        for k in range(n_batches):
            x, y = self.microbatch(split_base + k, 0)
            a = jnp.asarray(x)
            for j in range(self.n - 1):
                (a,) = self.jit[j]["fwd"](*theta[j], a)
            (logits,) = self.jit[self.n - 1]["predict"](*theta[self.n - 1], a)
            pred = np.asarray(jnp.argmax(logits, axis=-1))
            correct += int((pred == y).sum())
            total += len(y)
        return correct / total
