"""L2 correctness: staged models — shapes, composition, gradient integrity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, datagen
from compile.model import (
    ConvNet, ConvNetConfig, Mlp, MlpConfig, Transformer, TransformerConfig,
    build_model, make_stage_fns, split_layers,
)


def test_split_layers():
    assert split_layers(4, 4) == [1, 1, 1, 1]
    assert split_layers(12, 4) == [3, 3, 3, 3]
    assert split_layers(10, 4) == [3, 3, 2, 2]
    assert split_layers(2, 4) == [1, 1, 0, 0]


@pytest.fixture(scope="module")
def tiny():
    cfg = TransformerConfig()
    return Transformer(cfg), cfg


def test_transformer_stage_specs(tiny):
    model, cfg = tiny
    assert model.n_stages == 4
    # stage 0: embeddings + 1 layer; stage 3: 1 layer + final ln/head
    assert model.stage_specs[0][0].name == "tok_emb"
    assert model.stage_specs[3][-2].name == "w_head"
    assert len(model.stage_specs[1]) == 12


def test_transformer_fwd_shapes(tiny):
    model, cfg = tiny
    params = [[jnp.asarray(a) for a in st] for st in model.init_params(0)]
    x, tgt = datagen.lm_microbatch(1, 0, 0, cfg.microbatch, cfg.seq, cfg.vocab)
    a = jnp.asarray(x)
    y = model.stage_apply(0, params[0], a)
    assert y.shape == (cfg.microbatch, cfg.seq, cfg.d_model)
    y = model.stage_apply(1, params[1], y)
    y = model.stage_apply(2, params[2], y)
    loss = model.loss_apply(params[3], y, jnp.asarray(tgt))
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    # initial loss ~ log(V) for a random model
    assert abs(float(loss) - np.log(cfg.vocab)) < 1.0


def test_staged_grads_match_monolithic(tiny):
    """Chained per-stage vjp == grad of the composed model (the crucial
    decomposition the whole coordinator relies on)."""
    model, cfg = tiny
    params = [[jnp.asarray(a) for a in st] for st in model.init_params(0)]
    x, tgt = datagen.lm_microbatch(1, 0, 0, cfg.microbatch, cfg.seq, cfg.vocab)
    x, tgt = jnp.asarray(x), jnp.asarray(tgt)

    def full_loss(all_params):
        a = model.stage_apply(0, all_params[0], x)
        a = model.stage_apply(1, all_params[1], a)
        a = model.stage_apply(2, all_params[2], a)
        return model.loss_apply(all_params[3], a, tgt)

    want = jax.grad(full_loss)([tuple(p) for p in params])

    fns = [make_stage_fns(model, j) for j in range(4)]
    acts = [x]
    for j in range(3):
        (y,) = fns[j]["fwd"](*params[j], acts[j])
        acts.append(y)
    out = fns[3]["fwdbwd"](*params[3], acts[3], tgt)
    _, gx, got3 = out[0], out[1], out[2:]
    out = fns[2]["fwdbwd"](*params[2], acts[2], gx)
    gx, got2 = out[0], out[1:]
    out = fns[1]["fwdbwd"](*params[1], acts[1], gx)
    gx, got1 = out[0], out[1:]
    got0 = fns[0]["fwdbwd"](*params[0], acts[0], gx)

    for got_stage, want_stage in zip([got0, got1, got2, got3], want):
        for g, wnt in zip(got_stage, want_stage):
            np.testing.assert_allclose(
                np.asarray(g), np.asarray(wnt), rtol=2e-4, atol=2e-5
            )


@pytest.mark.parametrize("family,cfg", [
    ("mlp", MlpConfig(microbatch=4)),
    ("convnet", ConvNetConfig(microbatch=2, base_channels=8)),
])
def test_classifier_families_compose(family, cfg):
    model = build_model(family, cfg)
    params = [[jnp.asarray(a) for a in st] for st in model.init_params(0)]
    protos = datagen.class_prototypes(
        5, 10, cfg.input_dim if family != "mlp" else cfg.input_dim
    )
    x, y = datagen.class_microbatch(5, 0, 0, cfg.microbatch, protos)
    a = jnp.asarray(x)
    for j in range(model.n_stages - 1):
        a = model.stage_apply(j, params[j], a)
        assert a.shape == tuple(model.output_spec(j).shape)
    loss = model.loss_apply(params[-1], a, jnp.asarray(y))
    assert np.isfinite(float(loss))
    assert abs(float(loss) - np.log(10)) < 1.5
    logits = model.predict_apply(params[-1], a)
    assert logits.shape == (cfg.microbatch, 10)


def test_convnet_grads_flow_to_all_stages():
    cfg = ConvNetConfig(microbatch=2, base_channels=8)
    model = ConvNet(cfg)
    params = [[jnp.asarray(a) for a in st] for st in model.init_params(0)]
    protos = datagen.class_prototypes(5, 10, cfg.input_dim)
    x, y = datagen.class_microbatch(5, 0, 0, cfg.microbatch, protos)
    fns = [make_stage_fns(model, j) for j in range(4)]
    acts = [jnp.asarray(x)]
    for j in range(3):
        (a,) = fns[j]["fwd"](*params[j], acts[j])
        acts.append(a)
    out = fns[3]["fwdbwd"](*params[3], acts[3], jnp.asarray(y))
    gx = out[1]
    for j in (2, 1):
        out = fns[j]["fwdbwd"](*params[j], acts[j], gx)
        gx = out[0]
        assert all(np.isfinite(np.asarray(g)).all() for g in out[1:])
        assert any(float(jnp.abs(g).max()) > 0 for g in out[1:])
    g0 = fns[0]["fwdbwd"](*params[0], acts[0], gx)
    assert any(float(jnp.abs(g).max()) > 0 for g in g0)


def test_sgd_stage_fn_updates(tiny):
    model, _ = tiny
    fns = make_stage_fns(model, 1)
    params = [jnp.asarray(a) for a in model.init_params(0)[1]]
    moms = [jnp.zeros_like(p) for p in params]
    grads = [jnp.ones_like(p) for p in params]
    lr = jnp.asarray([0.1], dtype=jnp.float32)
    out = fns["sgd"](*params, *moms, *grads, lr)
    k = len(params)
    for p_new, p in zip(out[:k], params):
        np.testing.assert_allclose(
            np.asarray(p_new), np.asarray(p) - 0.1, rtol=1e-5, atol=1e-6
        )
    for m_new in out[k:]:
        np.testing.assert_allclose(np.asarray(m_new), 1.0, rtol=1e-6)


def test_act_bytes_and_flops_positive(tiny):
    model, _ = tiny
    for j in range(model.n_stages):
        assert model.stage_act_bytes(j) > 0
        assert model.stage_flops(j) > 0
    # last stage carries the vocab projection: most FLOPs for tiny
    assert model.stage_flops(3) > model.stage_flops(1)
