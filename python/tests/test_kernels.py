"""L1 correctness: Pallas kernels (interpret=True) vs pure-jnp oracles.

Hypothesis sweeps shapes; fixed tests pin the block-edge cases.  Tolerances
are fp32 accumulation-order tolerances, not behavioural slack.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, diff, layernorm, matmul, ref, sgd

jax.config.update("jax_platform_name", "cpu")


def rnd(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


# ------------------------------------------------------------ matmul -------
@settings(max_examples=25, deadline=None)
@given(
    m=st.sampled_from([1, 3, 8, 16, 64, 130]),
    k=st.sampled_from([1, 4, 32, 96, 128]),
    n=st.sampled_from([1, 5, 16, 48, 256]),
    act=st.sampled_from([None, "relu", "gelu"]),
    bias=st.booleans(),
)
def test_linear_matches_ref(m, k, n, act, bias):
    rng = np.random.default_rng(m * 1000 + k * 10 + n)
    x, w = rnd(rng, m, k), rnd(rng, k, n)
    b = rnd(rng, n) if bias else None
    got = matmul.linear(x, w, b, act)
    want = ref.linear_ref(x, w, b, act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("blocks", [(16, 16, 16), (32, 64, 16), (128, 128, 128)])
def test_linear_block_shapes_equivalent(blocks):
    """Block shape is a schedule choice: result must be block-invariant."""
    rng = np.random.default_rng(0)
    x, w, b = rnd(rng, 64, 128), rnd(rng, 128, 64), rnd(rng, 64)
    bm, bn, bk = blocks
    got = matmul.linear(x, w, b, "gelu", block_m=bm, block_n=bn, block_k=bk)
    want = ref.linear_ref(x, w, b, "gelu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_linear_rejects_mismatched_inner_dims():
    x, w = jnp.ones((4, 8)), jnp.ones((9, 4))
    with pytest.raises(AssertionError):
        matmul.linear(x, w)


def test_vmem_accounting():
    assert matmul.vmem_bytes(128, 128, 128) == 3 * 128 * 128 * 4
    assert matmul.mxu_alignment(128, 128, 128) == 1.0
    assert matmul.mxu_alignment(64, 128, 128) == 0.5


# --------------------------------------------------------- layernorm -------
@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([1, 2, 7, 64, 200]),
    d=st.sampled_from([4, 32, 128, 384]),
)
def test_layernorm_matches_ref(m, d):
    rng = np.random.default_rng(m + d)
    x, g, b = rnd(rng, m, d), rnd(rng, d), rnd(rng, d)
    got = layernorm.layernorm(x, g, b)
    want = ref.layernorm_ref(x, g, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_layernorm_zero_variance_row():
    x = jnp.ones((4, 16)) * 3.0  # constant rows: var = 0, rsqrt(eps) path
    g, b = jnp.ones(16), jnp.zeros(16)
    got = np.asarray(layernorm.layernorm(x, g, b))
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got, 0.0, atol=1e-3)


# --------------------------------------------------------- attention -------
@settings(max_examples=15, deadline=None)
@given(
    h=st.sampled_from([1, 2, 8]),
    s=st.sampled_from([1, 4, 16, 64]),
    dh=st.sampled_from([4, 16, 32]),
)
def test_attention_matches_ref(h, s, dh):
    rng = np.random.default_rng(h * 100 + s + dh)
    q, k, v = rnd(rng, h, s, dh), rnd(rng, h, s, dh), rnd(rng, h, s, dh)
    got = attention.attention(q, k, v)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_attention_is_causal():
    """Changing future keys/values must not change past outputs."""
    rng = np.random.default_rng(5)
    q, k, v = (rnd(rng, 2, 8, 4) for _ in range(3))
    base = np.asarray(attention.attention(q, k, v))
    k2 = k.at[:, -1, :].set(99.0)
    v2 = v.at[:, -1, :].set(-99.0)
    pert = np.asarray(attention.attention(q, k2, v2))
    np.testing.assert_allclose(base[:, :-1], pert[:, :-1], rtol=1e-5, atol=1e-6)
    assert not np.allclose(base[:, -1], pert[:, -1])


# --------------------------------------------------------------- sgd -------
@settings(max_examples=20, deadline=None)
@given(
    l=st.sampled_from([1, 3, 100, 1024, 5000]),
    lr=st.sampled_from([0.0, 0.01, 0.5]),
    mu=st.sampled_from([0.0, 0.9]),
)
def test_sgd_matches_ref(l, lr, mu):
    rng = np.random.default_rng(l)
    p, m, g = rnd(rng, l), rnd(rng, l), rnd(rng, l)
    lr_arr = jnp.asarray([lr], dtype=jnp.float32)
    p1, m1 = sgd.sgd_momentum_flat(p, m, g, lr_arr, mu)
    p2, m2 = ref.sgd_momentum_ref(p, m, g, lr_arr, mu)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), rtol=1e-6, atol=1e-6)


def test_sgd_shape_preserving():
    rng = np.random.default_rng(1)
    p = rnd(rng, 4, 6)
    m, g = jnp.zeros_like(p), rnd(rng, 4, 6)
    lr = jnp.asarray([0.1], dtype=jnp.float32)
    p1, m1 = sgd.sgd_momentum(p, m, g, lr)
    assert p1.shape == (4, 6) and m1.shape == (4, 6)
    np.testing.assert_allclose(
        np.asarray(p1), np.asarray(p - 0.1 * g), rtol=1e-6, atol=1e-7
    )


def test_sgd_zero_lr_keeps_params():
    rng = np.random.default_rng(2)
    p, m, g = rnd(rng, 64), rnd(rng, 64), rnd(rng, 64)
    lr = jnp.asarray([0.0], dtype=jnp.float32)
    p1, m1 = sgd.sgd_momentum_flat(p, m, g, lr)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p))
    # momentum still accumulates
    np.testing.assert_allclose(np.asarray(m1), np.asarray(0.9 * m + g), rtol=1e-6)


# ----------------------------------------------------- differentiability ---
def test_linear_grad_matches_jnp():
    rng = np.random.default_rng(3)
    x, w, b = rnd(rng, 16, 32), rnd(rng, 32, 8), rnd(rng, 8)

    def f_pallas(w, b):
        return jnp.sum(diff.linear(x, w, b, "gelu") ** 2)

    def f_ref(w, b):
        return jnp.sum(ref.linear_ref(x, w, b, "gelu") ** 2)

    gw1, gb1 = jax.grad(f_pallas, argnums=(0, 1))(w, b)
    gw2, gb2 = jax.grad(f_ref, argnums=(0, 1))(w, b)
    np.testing.assert_allclose(np.asarray(gw1), np.asarray(gw2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gb1), np.asarray(gb2), rtol=1e-4, atol=1e-4)


def test_layernorm_grad_matches_jnp():
    rng = np.random.default_rng(4)
    x, g, b = rnd(rng, 8, 32), rnd(rng, 32), rnd(rng, 32)
    g1 = jax.grad(lambda x: jnp.sum(jnp.sin(diff.layernorm(x, g, b))))(x)
    g2 = jax.grad(lambda x: jnp.sum(jnp.sin(ref.layernorm_ref(x, g, b))))(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4, atol=1e-4)


def test_attention_grad_matches_jnp():
    rng = np.random.default_rng(6)
    q, k, v = (rnd(rng, 2, 8, 4) for _ in range(3))
    g1 = jax.grad(lambda q: jnp.sum(diff.attention(q, k, v) ** 2))(q)
    g2 = jax.grad(lambda q: jnp.sum(ref.attention_ref(q, k, v) ** 2))(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4, atol=1e-4)
