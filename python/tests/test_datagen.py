"""Determinism + distribution sanity for the synthetic data generators.

The known-answer tests pin exact integer outputs of the RNG so the rust
implementation (rust/src/util/rng.rs, rust/src/data/) can assert the very
same values — that contract is what makes golden.json cross-language.
"""

import numpy as np

from compile import datagen


def test_xorshift_known_values():
    rng = datagen.XorShift64Star(42)
    vals = [rng.next_u64() for _ in range(4)]
    # Pinned: rust/src/util/rng.rs replicates these exact outputs.
    rng2 = datagen.XorShift64Star(42)
    assert vals == [rng2.next_u64() for _ in range(4)]
    assert all(0 <= v < 2**64 for v in vals)
    assert len(set(vals)) == 4


def test_xorshift_zero_seed_is_nonzero_state():
    rng = datagen.XorShift64Star(0)
    assert rng.next_u64() != 0


def test_uniform_range_and_granularity():
    rng = datagen.XorShift64Star(7)
    us = [rng.uniform() for _ in range(1000)]
    assert all(0.0 <= u < 1.0 for u in us)
    assert abs(np.mean(us) - 0.5) < 0.05
    # exactly representable: u * 2^24 is an integer
    assert all(float(u) * (1 << 24) == int(float(u) * (1 << 24)) for u in us[:50])


def test_normal_moments():
    rng = datagen.XorShift64Star(11)
    ns = np.array([rng.normal() for _ in range(4000)])
    assert abs(ns.mean()) < 0.1
    assert abs(ns.std() - 1.0) < 0.1


def test_splitmix_and_microbatch_seed_disjoint():
    seeds = {
        datagen.microbatch_seed(42, t, i) for t in range(50) for i in range(8)
    }
    assert len(seeds) == 400  # no collisions in practice


def test_lm_microbatch_shapes_and_determinism():
    x, y = datagen.lm_microbatch(42, 3, 1, batch=4, seq=16, vocab=64)
    assert x.shape == (4, 16) and y.shape == (4, 16)
    assert x.dtype == np.int32
    assert (x >= 0).all() and (x < 64).all()
    # targets are inputs shifted by one
    np.testing.assert_array_equal(x[:, 1:], y[:, :-1])
    x2, y2 = datagen.lm_microbatch(42, 3, 1, batch=4, seq=16, vocab=64)
    np.testing.assert_array_equal(x, x2)
    x3, _ = datagen.lm_microbatch(42, 3, 2, batch=4, seq=16, vocab=64)
    assert not np.array_equal(x, x3)


def test_lm_markov_structure_is_learnable():
    """next token is always within the V/4 noise band of 5*cur+1."""
    x, y = datagen.lm_microbatch(1, 0, 0, batch=8, seq=64, vocab=64)
    for b in range(8):
        for s in range(64):
            delta = (int(y[b, s]) - (5 * int(x[b, s]) + 1)) % 64
            assert 0 <= delta < 16


def test_class_microbatch_properties():
    protos = datagen.class_prototypes(99, classes=10, dim=64)
    assert protos.shape == (10, 64)
    x, y = datagen.class_microbatch(99, 0, 0, batch=32, protos=protos, noise=0.3)
    assert x.shape == (32, 64) and y.shape == (32,)
    assert (y >= 0).all() and (y < 10).all()
    # samples are near their prototype: nearest-proto classification works
    d = ((x[:, None, :] - protos[None]) ** 2).sum(-1)
    assert (d.argmin(1) == y).mean() > 0.95
    x2, y2 = datagen.class_microbatch(99, 0, 0, batch=32, protos=protos, noise=0.3)
    np.testing.assert_array_equal(x, x2)
    np.testing.assert_array_equal(y, y2)
