"""Update-rule semantics (paper Sec. 3.2) verified on the python mirror."""

import numpy as np
import pytest

from compile import configs, mirror
from compile.model import MlpConfig, Mlp
from compile.mirror import MirrorTrainer, use_fresh


def test_use_fresh_dp_and_v1():
    for i in range(1, 5):
        for j in range(1, 5):
            assert use_fresh("dp", i, j, 4)
            assert not use_fresh("cdp_v1", i, j, 4)


def test_use_fresh_v2_suffix_pattern():
    n = 4
    # micro-batch 1 sees fresh only for stage N; micro-batch N all fresh.
    assert [use_fresh("cdp_v2", 1, j, n) for j in range(1, 5)] == [
        False, False, False, True,
    ]
    assert [use_fresh("cdp_v2", 4, j, n) for j in range(1, 5)] == [True] * 4
    assert [use_fresh("cdp_v2", 2, j, n) for j in range(1, 5)] == [
        False, False, True, True,
    ]


def test_use_fresh_unknown_rule():
    with pytest.raises(ValueError):
        use_fresh("bogus", 1, 1, 4)


@pytest.fixture(scope="module")
def mlp_setup():
    cfg = MlpConfig(classes=4, input_dim=16, hidden=32,
                    layers_per_stage=1, microbatch=4, n_stages=4)
    model = Mlp(cfg)
    data = dict(kind="class", classes=4, input_dim=16, noise=0.3,
                batch=4, seed=5)
    params0 = model.init_params(3)
    return model, data, params0


def test_rules_agree_at_step0(mlp_setup):
    """θ_{-1} := θ_0 bootstrap ⇒ all rules produce the same first loss."""
    model, data, params0 = mlp_setup
    tr = MirrorTrainer(model, data, lr=0.05)
    first = {r: tr.train(params0, r, 1)[0][0] for r in mirror.RULES}
    assert first["dp"] == pytest.approx(first["cdp_v1"], rel=1e-6)
    assert first["dp"] == pytest.approx(first["cdp_v2"], rel=1e-6)


def test_rules_diverge_then_all_learn(mlp_setup):
    model, data, params0 = mlp_setup
    tr = MirrorTrainer(model, data, lr=0.05)
    curves = {r: tr.train(params0, r, 12)[0] for r in mirror.RULES}
    # delayed rules differ from DP after the first step
    assert curves["dp"][2] != curves["cdp_v1"][2]
    assert curves["cdp_v1"][2] != curves["cdp_v2"][2]
    # but every rule trains: final loss well under initial
    for r, c in curves.items():
        assert c[-1] < c[0] * 0.9, (r, c)


def test_n1_degenerate_case():
    """N = 1: CDP-v2's single micro-batch sees the fresh parameters
    (j = 1 ≥ N−i+1 = 1), so CDP-v2 ≡ DP exactly.  CDP-v1 however remains
    *delayed-by-one SGD* even for N = 1 (θ̂ = θ_{t−1}) — a genuinely
    different trajectory after the bootstrap step."""
    cfg = MlpConfig(classes=4, input_dim=16, hidden=32,
                    layers_per_stage=2, microbatch=4, n_stages=1)
    model = Mlp(cfg)
    data = dict(kind="class", classes=4, input_dim=16, noise=0.3,
                batch=4, seed=5)
    params0 = model.init_params(0)
    tr = MirrorTrainer(model, data, lr=0.05)
    curves = {r: tr.train(params0, r, 5)[0] for r in mirror.RULES}
    np.testing.assert_allclose(curves["dp"], curves["cdp_v2"], rtol=1e-6)
    # bootstrap: first step identical; delay visible from step 1 on
    assert curves["dp"][0] == pytest.approx(curves["cdp_v1"][0], rel=1e-6)
    assert curves["dp"][1] != curves["cdp_v1"][1]
    # and delayed SGD still converges (paper Sec 3.2 remark)
    assert curves["cdp_v1"][-1] < curves["cdp_v1"][0]


def test_v2_is_between_dp_and_v1_in_staleness(mlp_setup):
    """CDP-v2 uses strictly fewer stale stage-params than CDP-v1 and more
    than DP: count over the (i, j) grid."""
    n = 6
    stale = {
        r: sum(
            not use_fresh(r, i, j, n)
            for i in range(1, n + 1)
            for j in range(1, n + 1)
        )
        for r in mirror.RULES
    }
    assert stale["dp"] == 0
    assert stale["cdp_v1"] == n * n
    # mb i has N−i stale stages ⇒ Σ_{i=1..N} (N−i) = N(N−1)/2
    assert stale["cdp_v2"] == n * (n - 1) / 2
    assert 0 < stale["cdp_v2"] < n * n


def test_classifier_actually_learns_to_accuracy(mlp_setup):
    model, data, params0 = mlp_setup
    tr = MirrorTrainer(model, data, lr=0.1)
    _, theta = tr.train(params0, "cdp_v2", 30)
    acc = tr.accuracy(theta, n_batches=4)
    assert acc > 0.5  # 4 classes, random = 0.25
