"""AOT pipeline: HLO text generation, manifest consistency, params.bin layout."""

import json
import os

import numpy as np
import pytest

from compile import aot, configs
from compile.model import make_stage_fns


@pytest.fixture(scope="module")
def built_tiny(tmp_path_factory):
    out = tmp_path_factory.mktemp("bundles")
    aot.build_bundle("tiny", str(out), skip_golden=True)
    return os.path.join(str(out), "tiny")


def test_hlo_text_is_parseable_hlo(built_tiny):
    text = open(os.path.join(built_tiny, "stage0_fwd.hlo.txt")).read()
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # no Mosaic custom-calls may leak in (interpret=True contract)
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


def test_manifest_structure(built_tiny):
    m = json.load(open(os.path.join(built_tiny, "manifest.json")))
    assert m["n_stages"] == 4 and m["n_microbatches"] == 4
    assert len(m["stages"]) == 4
    for j, st in enumerate(m["stages"]):
        assert st["index"] == j
        assert st["n_params"] == len(st["params"])
        for art in st["artifacts"].values():
            assert os.path.exists(os.path.join(built_tiny, art)), art
        assert st["act_bytes"] > 0 and st["flops"] > 0
    assert m["stages"][0]["input"]["dtype"] == "i32"
    assert m["stages"][1]["input"]["dtype"] == "f32"
    assert m["stages"][3]["output"] is None


def test_params_bin_matches_manifest(built_tiny):
    m = json.load(open(os.path.join(built_tiny, "manifest.json")))
    total = sum(st["param_elems"] for st in m["stages"])
    assert total == m["total_param_elems"]
    raw = np.fromfile(os.path.join(built_tiny, "params.bin"), dtype="<f4")
    assert raw.size == total
    # reproducible init: same seed → same bytes
    bc = configs.bundle_config("tiny")
    model = configs.make_bundle_model(bc)
    p0 = model.init_params(bc["seed"])
    flat = np.concatenate([a.ravel() for st in p0 for a in st])
    np.testing.assert_array_equal(raw, flat.astype("<f4"))


def test_all_bundle_configs_resolve():
    for name in ("tiny", "mlp", "convnet", "lm_small", "lm_gpt2s"):
        bc = configs.bundle_config(name)
        model = configs.make_bundle_model(bc)
        assert model.n_stages == bc["cfg"].n_stages
        # staged fns construct without error for every stage
        for j in range(model.n_stages):
            make_stage_fns(model, j)
    with pytest.raises(ValueError):
        configs.bundle_config("nope")


def test_gpt2s_is_100m_class():
    bc = configs.bundle_config("lm_gpt2s")
    model = configs.make_bundle_model(bc)
    total = sum(s.elems for st in model.stage_specs for s in st)
    assert 90e6 < total < 150e6, total
