//! Fig 4 reproduction: activation memory per worker when training with N
//! workers under DP (in-phase: per-worker memory = the single-pass curve)
//! vs CDP (staggered: per-worker memory = the cyclic mean), for ResNet-50
//! and ViT-B/16 analytic profiles, N ∈ {4, 8, 32}.
//!
//! Run: `cargo run --release --example memory_tracking -- --batch 64 --out results/fig4.csv`

use cyclic_dp::cli::Args;
use cyclic_dp::memsim::{extrapolate, resnet50_profile, vit_b16_profile, MemoryCurve};
use cyclic_dp::metrics::Metrics;
use cyclic_dp::util::stats::fmt_bytes;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    let batch = args.u64_or("batch", 64);
    let out = args.str_or("out", "results/fig4.csv").to_string();
    let ns = [4usize, 8, 32];

    let mut metrics = Metrics::new();
    for (arch, layers) in [
        ("resnet50", resnet50_profile(batch)),
        ("vit_b16", vit_b16_profile(batch)),
    ] {
        let curve = MemoryCurve::from_layers(&layers);
        println!(
            "\n=== {arch} (batch {batch}) — single-pass activation curve: peak {}, mean {} ===",
            fmt_bytes(curve.peak() as u64),
            fmt_bytes(curve.mean() as u64)
        );
        for n in ns {
            let e = extrapolate(&curve, n, 512);
            for (tau, dp, cdp) in e.samples.iter().step_by(8) {
                metrics.record(&format!("{arch}_dp_n{n}"), *tau, *dp);
                metrics.record(&format!("{arch}_cdp_n{n}"), *tau, *cdp);
            }
            println!(
                "N={:<3} DP peak/worker {:>10}  CDP peak/worker {:>10}  reduction {:>5.1}%",
                n,
                fmt_bytes(e.dp_peak as u64),
                fmt_bytes(e.cdp_peak as u64),
                e.reduction * 100.0
            );
        }
        // optimal halving reference line (paper's 'Optimal')
        let e32 = extrapolate(&curve, 32, 512);
        println!(
            "   optimal halving = {} | CDP N=32 reaches {}",
            fmt_bytes((e32.dp_peak / 2.0) as u64),
            fmt_bytes(e32.cdp_peak as u64)
        );
    }

    let names: Vec<String> = ["resnet50", "vit_b16"]
        .iter()
        .flat_map(|a| {
            ns.iter().flat_map(move |n| {
                [format!("{a}_dp_n{n}"), format!("{a}_cdp_n{n}")]
            })
        })
        .collect();
    let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    metrics.write_series_csv(std::path::Path::new(&out), &refs)?;
    println!("\nwrote Fig-4 curves to {out}");
    println!(
        "paper shape: CDP flattens as N grows; ViT (homogeneous) ≈42% saving, \
         ResNet (heterogeneous) ≈30%"
    );
    Ok(())
}
