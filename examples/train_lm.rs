//! End-to-end driver (Fig 3 analog): train a GPT-style LM through the full
//! stack — Pallas kernels (L1) in staged JAX fwd/bwd HLO (L2) driven by the
//! rust coordinator (L3) — for a few hundred steps, logging the loss curve
//! of each update rule to CSV.
//!
//! Bundles: `lm_small` (default, ~7M params), `lm_gpt2s` (~110M, build with
//! `cd python && python -m compile.aot --out-root ../artifacts --bundles lm_gpt2s`).
//!
//! Run: `cargo run --release --features xla --example train_lm -- --bundle lm_small --steps 300`
//!
//! Transformer bundles execute on the XLA backend only — without the
//! `xla` feature this example prints a build hint and exits.

#[cfg(not(feature = "xla"))]
fn main() {
    eprintln!(
        "train_lm drives transformer bundles, which need the XLA backend: \
         rebuild with `cargo run --release --features xla --example train_lm` \
         (and `make artifacts` for the bundle)"
    );
}

#[cfg(feature = "xla")]
use std::time::Instant;

#[cfg(feature = "xla")]
use cyclic_dp::cli::Args;
#[cfg(feature = "xla")]
use cyclic_dp::coordinator::single::RefTrainer;
#[cfg(feature = "xla")]
use cyclic_dp::metrics::Metrics;
#[cfg(feature = "xla")]
use cyclic_dp::model::artifacts_root;
#[cfg(feature = "xla")]
use cyclic_dp::parallel::rule_by_name;
#[cfg(feature = "xla")]
use cyclic_dp::runtime::BundleRuntime;

#[cfg(feature = "xla")]
fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    let bundle = args.str_or("bundle", "lm_small");
    let steps = args.usize_or("steps", 300);
    let rules: Vec<String> = args
        .str_or("rules", "dp,cdp_v1,cdp_v2")
        .split(',')
        .map(String::from)
        .collect();
    let out = args.str_or("out", "results/fig3_losscurve.csv").to_string();

    let dir = artifacts_root().join(bundle);
    let t0 = Instant::now();
    let rt = BundleRuntime::load(&dir)?;
    println!(
        "bundle {} loaded+compiled in {:.1}s — {} params, {} stages, seq {:?}",
        bundle,
        t0.elapsed().as_secs_f64(),
        rt.manifest.total_param_elems,
        rt.manifest.n_stages,
        rt.manifest.stages.last().unwrap().input.shape,
    );
    let tokens_per_step = {
        let s = &rt.manifest.stages[0].input.shape;
        s.iter().product::<usize>() * rt.manifest.n_microbatches
    };

    let mut metrics = Metrics::new();
    for rule_name in &rules {
        let rule = rule_by_name(rule_name)?;
        let mut trainer = RefTrainer::new(&rt, rule)?;
        let t1 = Instant::now();
        println!("\n=== rule {rule_name}: {steps} steps ===");
        let mut last_print = Instant::now();
        for s in 0..steps {
            let log = trainer.step()?;
            metrics.record(&format!("loss_{rule_name}"), s as f64, log.loss);
            if last_print.elapsed().as_secs() >= 10 || s == steps - 1 || s < 3 {
                let sps = (s + 1) as f64 / t1.elapsed().as_secs_f64();
                println!(
                    "step {:>5}  loss {:.4}  ({:.2} steps/s, {:.0} tok/s)",
                    s,
                    log.loss,
                    sps,
                    sps * tokens_per_step as f64
                );
                last_print = Instant::now();
            }
        }
        let eval = trainer.eval_loss(8)?;
        println!(
            "rule {rule_name}: final train loss {:.4}, eval loss {:.4}, {:.1}s total",
            metrics
                .get_series(&format!("loss_{rule_name}"))
                .unwrap()
                .last()
                .unwrap(),
            eval,
            t1.elapsed().as_secs_f64()
        );
        metrics.record(&format!("eval_{rule_name}"), steps as f64, eval);
    }

    let names: Vec<String> = rules.iter().map(|r| format!("loss_{r}")).collect();
    let name_refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    metrics.write_series_csv(std::path::Path::new(&out), &name_refs)?;
    println!("\nwrote loss curves to {out}");

    // Fig-3 shape check: smoothed early-loss ordering (v1 highest early)
    if rules.len() == 3 {
        let window = (steps / 10).max(1);
        let early = |r: &str| {
            let s = metrics.get_series(&format!("loss_{r}")).unwrap();
            let sm = s.smoothed(window);
            sm.get(window.min(sm.len() - 1)).map(|(_, v)| *v).unwrap_or(0.0)
        };
        println!(
            "early smoothed losses — dp {:.4} | cdp_v1 {:.4} | cdp_v2 {:.4} \
             (paper: v1 visibly higher early, v2 ≈ dp)",
            early("dp"),
            early("cdp_v1"),
            early("cdp_v2")
        );
    }
    Ok(())
}
