//! Fig 1 reproduction: render the DP and CDP execution timelines and the
//! activation/communication properties the paper reads off them.
//!
//! Run: `cargo run --release --example timeline -- --n 3`

use cyclic_dp::cli::Args;
use cyclic_dp::parallel::Schedule;

fn main() {
    let args = Args::parse_env();
    let n = args.usize_or("n", 3);
    let horizon = args.usize_or("horizon", 8 * n);

    let dp = Schedule::dp(n, horizon);
    let cdp = Schedule::cyclic(n, horizon);

    println!("=== Fig 1a — DP, N={n}: lockstep + barrier every {} steps ===", 2 * n);
    print!("{}", dp.render(4 * n));
    println!("barriers at time steps: {:?}\n", dp.barrier_steps(4 * n));

    println!("=== Fig 1b/c — CDP, N={n}: uniform delay 2(i-1), no barrier ===");
    print!("{}", cdp.render(4 * n));

    println!("\nactivation stashes per time step (total across workers):");
    print!("  DP : ");
    for k in 0..4 * n {
        print!("{:>3}", dp.total_stashes_after(k));
    }
    print!("\n  CDP: ");
    for k in 0..4 * n {
        print!("{:>3}", cdp.total_stashes_after(k));
    }
    let (dpk, _) = dp.stash_stats();
    let (ck, cs) = cdp.stash_stats();
    println!(
        "\n\npeaks: DP {dpk} vs CDP {ck} (steady {cs:.1}) — CDP ≈ constant at ~half the DP peak"
    );

    println!("\ngradient hand-offs after each step (CDP ring, from→to stage):");
    for k in 2 * n..4 * n {
        let h = cdp.handoffs_after(k);
        if !h.is_empty() {
            let s: Vec<String> = h
                .iter()
                .map(|(f, t, st)| format!("w{f}→w{t} (stage {st})"))
                .collect();
            println!("  t={k}: {}", s.join(", "));
        }
    }
}
