//! Table 2 analog: train classifiers under DP / CDP-v1 / CDP-v2 with
//! multiple seeds and report held-out accuracy per rule — the paper's
//! "does the gradient delay hurt final quality?" experiment on the
//! synthetic classification substitute (DESIGN.md substitution #2).
//!
//! Runs on the native backend with no artifacts (synthetic mlp):
//!
//!   cargo run --release --example classify -- --steps 60 --seeds 5
//!
//! The convnet variant needs the `xla` feature + `make artifacts`:
//!
//!   cargo run --release --features xla --example classify -- \
//!       --backend xla --bundle convnet --steps 60 --seeds 5

use cyclic_dp::cli::Args;
use cyclic_dp::coordinator::single::RefTrainer;
use cyclic_dp::data::DataSource;
use cyclic_dp::model::DataSpec;
use cyclic_dp::parallel::rule_by_name;
use cyclic_dp::runtime::{backend_choice, Backend, BackendChoice, NativeBackend};
use cyclic_dp::util::stats::Summary;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    match backend_choice(args.get("backend"))? {
        BackendChoice::Native => {
            run(NativeBackend::load_or_synthetic(args.str_or("bundle", "mlp"))?, &args)
        }
        BackendChoice::Xla => run_xla(&args),
    }
}

#[cfg(feature = "xla")]
fn run_xla(args: &Args) -> anyhow::Result<()> {
    let dir = cyclic_dp::model::artifacts_root().join(args.str_or("bundle", "mlp"));
    run(cyclic_dp::runtime::BundleRuntime::load(&dir)?, args)
}

#[cfg(not(feature = "xla"))]
fn run_xla(_args: &Args) -> anyhow::Result<()> {
    unreachable!("backend_choice rejects xla without the feature")
}

fn run<B: Backend>(rt: B, args: &Args) -> anyhow::Result<()> {
    let steps = args.usize_or("steps", 60);
    let seeds = args.u64_or("seeds", 3);
    // Optional noise override: the bundle's default (0.3) makes the task
    // nearly separable; ~2.0 pushes accuracy off the ceiling so rule
    // differences (if any) would be visible — the paper's Table-2 question.
    let noise_override = args.get("noise").map(|v| v.parse::<f32>().expect("--noise"));

    anyhow::ensure!(
        matches!(rt.manifest().data, DataSpec::Class { .. }),
        "classify needs a classification bundle (mlp or convnet)"
    );
    println!(
        "Table 2 analog — bundle {} ({} backend), {} params, {steps} steps × {seeds} seeds",
        rt.manifest().name,
        rt.name(),
        rt.manifest().total_param_elems
    );
    println!("{:<8} {:>10} {:>8}", "rule", "acc mean", "std");

    for rule_name in ["dp", "cdp_v1", "cdp_v2"] {
        let mut acc = Summary::new();
        for seed in 0..seeds {
            let rule = rule_by_name(rule_name)?;
            let mut t = RefTrainer::new(&rt, rule)?;
            // shift the data stream per seed (same distribution)
            if let DataSpec::Class { classes, input_dim, batch, noise, seed: s } =
                rt.manifest().data.clone()
            {
                t.data = DataSource::new(DataSpec::Class {
                    classes,
                    input_dim,
                    batch,
                    noise: noise_override.unwrap_or(noise),
                    seed: s + seed * 7919,
                });
            }
            t.train(steps)?;
            acc.add(t.accuracy(8)?);
        }
        println!("{:<8} {:>9.2}% {:>7.3}", rule_name, acc.mean() * 100.0, acc.std());
    }
    println!(
        "\npaper shape: all three rules within noise of each other \
         (CDP-v2 ≥ CDP-v1 on CIFAR-10)"
    );
    Ok(())
}
