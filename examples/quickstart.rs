//! Quickstart: train a bundle under all three update rules and watch the
//! losses coincide at step 0 (bootstrap) then track each other — the
//! paper's core claim that the CDP delay is benign.
//!
//! Runs out of the box on the pure-Rust backend (synthetic mlp bundle,
//! no artifacts, no XLA):
//!
//!   cargo run --release --example quickstart
//!
//! With the XLA feature + artifacts, the tiny transformer instead:
//!
//!   make artifacts && cargo run --release --features xla \
//!       --example quickstart -- --backend xla --bundle tiny

use cyclic_dp::cli::Args;
use cyclic_dp::coordinator::single::RefTrainer;
use cyclic_dp::parallel::Rule;
use cyclic_dp::runtime::{backend_choice, Backend, BackendChoice, NativeBackend};

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    // this example defaults to the zero-setup native backend; `--backend`
    // or CDP_BACKEND opt into xla explicitly
    let cli = args.get("backend");
    let choice = if cli.is_none() && std::env::var("CDP_BACKEND").is_err() {
        BackendChoice::Native
    } else {
        backend_choice(cli)?
    };
    match choice {
        BackendChoice::Native => {
            run(NativeBackend::load_or_synthetic(args.str_or("bundle", "native_mlp"))?)
        }
        BackendChoice::Xla => run_xla(&args),
    }
}

#[cfg(feature = "xla")]
fn run_xla(args: &Args) -> anyhow::Result<()> {
    let dir = cyclic_dp::model::artifacts_root().join(args.str_or("bundle", "tiny"));
    println!("loading bundle {dir:?} (PJRT CPU, HLO-text artifacts)…");
    run(cyclic_dp::runtime::BundleRuntime::load(&dir)?)
}

#[cfg(not(feature = "xla"))]
fn run_xla(_args: &Args) -> anyhow::Result<()> {
    unreachable!("backend_choice rejects xla without the feature")
}

fn run<B: Backend>(rt: B) -> anyhow::Result<()> {
    println!(
        "model: {} ({} backend) | {} stages | {} params | micro-batch {:?}",
        rt.manifest().family,
        rt.name(),
        rt.manifest().n_stages,
        rt.manifest().total_param_elems,
        rt.manifest().stages[0].input.shape,
    );

    let steps = 12;
    let mut curves = Vec::new();
    for rule in [Rule::Dp, Rule::CdpV1, Rule::CdpV2] {
        let mut t = RefTrainer::new(&rt, rule.clone())?;
        let logs = t.train(steps)?;
        println!("\n--- rule {} ---", rule.name());
        for l in &logs {
            println!("step {:>3}  loss {:.5}", l.step, l.loss);
        }
        curves.push((rule.name(), logs));
    }

    println!("\nstep-0 losses identical across rules (θ_-1 := θ_0 bootstrap):");
    for (name, logs) in &curves {
        println!("  {name:>7}: {:.6}", logs[0].loss);
    }
    let final_losses: Vec<f64> = curves.iter().map(|(_, l)| l[steps - 1].loss).collect();
    println!(
        "final losses: dp {:.4} | cdp_v1 {:.4} | cdp_v2 {:.4}",
        final_losses[0], final_losses[1], final_losses[2]
    );
    Ok(())
}
