//! Quickstart: train the tiny transformer bundle under all three update
//! rules and watch the losses coincide at step 0 (bootstrap) then track
//! each other — the paper's core claim that the CDP delay is benign.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use cyclic_dp::coordinator::single::RefTrainer;
use cyclic_dp::model::artifacts_root;
use cyclic_dp::parallel::Rule;
use cyclic_dp::runtime::BundleRuntime;

fn main() -> anyhow::Result<()> {
    let dir = artifacts_root().join("tiny");
    println!("loading bundle {dir:?} (PJRT CPU, HLO-text artifacts)…");
    let rt = BundleRuntime::load(&dir)?;
    println!(
        "model: {} | {} stages | {} params | micro-batch {:?}",
        rt.manifest.family,
        rt.manifest.n_stages,
        rt.manifest.total_param_elems,
        rt.manifest.stages[0].input.shape,
    );

    let steps = 12;
    let mut curves = Vec::new();
    for rule in [Rule::Dp, Rule::CdpV1, Rule::CdpV2] {
        let mut t = RefTrainer::new(&rt, rule.clone())?;
        let logs = t.train(steps)?;
        println!("\n--- rule {} ---", rule.name());
        for l in &logs {
            println!("step {:>3}  loss {:.5}", l.step, l.loss);
        }
        curves.push((rule.name(), logs));
    }

    println!("\nstep-0 losses identical across rules (θ_-1 := θ_0 bootstrap):");
    for (name, logs) in &curves {
        println!("  {name:>7}: {:.6}", logs[0].loss);
    }
    let final_losses: Vec<f64> = curves.iter().map(|(_, l)| l[steps - 1].loss).collect();
    println!(
        "final losses: dp {:.4} | cdp_v1 {:.4} | cdp_v2 {:.4}",
        final_losses[0], final_losses[1], final_losses[2]
    );
    Ok(())
}
