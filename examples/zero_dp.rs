//! ZeRO-DP vs ZeRO+CDP (paper §4.4): train with stage-sharded model states
//! and compare the state-distribution patterns — broadcast collectives vs
//! cyclic point-to-point hand-offs — while verifying the losses are
//! identical to the reference trainer.
//!
//! Runs on the native backend with no artifacts (synthetic mlp):
//!
//!   cargo run --release --example zero_dp -- --steps 8
//!
//! Or against an XLA bundle: `--features xla` + `--backend xla --bundle mlp`.

use std::sync::Arc;

use cyclic_dp::cli::Args;
use cyclic_dp::coordinator::{single, zero, SharedBackend};
use cyclic_dp::parallel::Rule;
use cyclic_dp::runtime::{backend_choice, Backend, BackendChoice, NativeBackend};
use cyclic_dp::util::stats::fmt_bytes;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    match backend_choice(args.get("backend"))? {
        BackendChoice::Native => {
            run(NativeBackend::load_or_synthetic(args.str_or("bundle", "mlp"))?, &args)
        }
        BackendChoice::Xla => run_xla(&args),
    }
}

#[cfg(feature = "xla")]
fn run_xla(args: &Args) -> anyhow::Result<()> {
    let dir = cyclic_dp::model::artifacts_root().join(args.str_or("bundle", "mlp"));
    run(cyclic_dp::runtime::BundleRuntime::load(&dir)?, args)
}

#[cfg(not(feature = "xla"))]
fn run_xla(_args: &Args) -> anyhow::Result<()> {
    unreachable!("backend_choice rejects xla without the feature")
}

fn run<B: Backend + Send + Sync + 'static>(backend: B, args: &Args) -> anyhow::Result<()> {
    let steps = args.usize_or("steps", 8);
    let rt = SharedBackend(Arc::new(backend));
    let full_model = rt.manifest().psi_p_bytes();
    println!(
        "bundle {} ({} backend): Ψ_P = {} across {} stage shards\n",
        rt.manifest().name,
        rt.name(),
        fmt_bytes(full_model),
        rt.manifest().n_stages
    );

    let mut reference = single::RefTrainer::new(&*rt.0, Rule::Dp)?;
    let ref_losses: Vec<f64> =
        reference.train(steps)?.iter().map(|l| l.loss).collect();

    for (name, rule, flow) in [
        ("ZeRO-DP (broadcast)", Rule::Dp, zero::StateFlow::Broadcast),
        ("ZeRO + CDP (cyclic p2p)", Rule::CdpV2, zero::StateFlow::Cyclic),
    ] {
        let rep = zero::train(rt.clone(), rule.clone(), flow, steps)?;
        println!("=== {name} ===");
        for l in &rep.logs {
            println!("  step {:>3}  loss {:.5}", l.step, l.loss);
        }
        if rule == Rule::Dp {
            let same = rep
                .logs
                .iter()
                .zip(&ref_losses)
                .all(|(l, r)| (l.loss - r).abs() < 1e-12);
            println!("  bit-identical to single-process DP reference: {same}");
        }
        println!(
            "  comm volume {} in {} msgs | max param-msgs per time step: {} \
             | peak state/worker {} ({}× full model)\n",
            fmt_bytes(rep.comm_bytes),
            rep.comm_messages,
            rep.max_msgs_per_timestep,
            fmt_bytes(rep.peak_state_bytes),
            rep.peak_state_bytes as f64 / full_model as f64
        );
    }
    println!(
        "paper shape: volume unchanged, but the per-time-step collective \
         (N−1 msgs) becomes a single point-to-point hand-off"
    );
    Ok(())
}
